//! Layer 8 — static analysis: the sample-free plan auditor.
//!
//! Vortex's selection thesis — hardware structure lets you reason
//! about the whole dynamic-shape strategy space without runtime
//! samples — applies to *correctness* too. Every invariant the runtime
//! and serving layers depend on is finitely checkable once it is
//! phrased over the `ceil(dim / extent)` lattice instead of over raw
//! shapes, so [`PlanAuditor`] proves them **symbolically over each
//! axis interval**, never at sampled points:
//!
//! 1. **Write-set disjointness** — for every (op, kernel) the
//!    `run_cells` launch grid's output regions are pairwise disjoint
//!    and exactly cover the output, including zero-padded edge chunks
//!    and beyond-grid batch chunks. The model is the per-axis
//!    [`OpSpec::write_axes`] / [`OpSpec::write_footprint`] hooks;
//!    footprints are per-axis interval boxes, so cross-axis
//!    disjointness and cover follow from the per-axis partitions
//!    (two distinct cells differ in at least one axis coordinate).
//!    Per axis, the dim range is split at L1-extent multiples; within
//!    one segment the grid is constant and every footprint is an
//!    affine function of the dim (constant for non-terminal cells,
//!    `end = d` for the terminal cell), so checking both segment
//!    endpoints plus non-terminal stability proves every in-segment
//!    shape — the same monotone-segment argument the dispatch layer
//!    uses for selection.
//! 2. **Capacity bounds** — `OpSpec::working_set` is documented
//!    monotone in every tile dim and edge tiles are zero-padded to the
//!    full tile, so its supremum over every admissible runtime shape
//!    is attained at the closed-form per-axis extrema corner
//!    ([`OpSpec::axis_extrema`]). One evaluation per (kernel, level)
//!    bounds all shapes.
//! 3. **Dispatch-region soundness** — for every
//!    [`DispatchTable`] cell, the recorded winner's chain-scaled
//!    [`FastKernel`](crate::coordinator::Selector) estimate must be
//!    the first strict argmin over every eligible rival across the
//!    WHOLE cell. Estimates depend on dims only through the launch
//!    grid, and the audit's fine lattice splits every axis at every
//!    eligible L1-extent multiple, so one representative per fine cell
//!    (the upper edge) is a proof, not a sample; the audit also checks
//!    every stored (merged) edge lies ON that lattice — a tampered
//!    edge cannot hide between two grid-constant segments.
//! 4. **Artifact/alias consistency** — `measurement_op` alias chains
//!    reach a fixpoint with ranks preserved, backend dtypes agree with
//!    library dtypes, manifest `artifact_name`s resolve (when a
//!    manifest is supplied), and embedded schema-v3 table payloads
//!    carry matching selector fingerprints and content digests.
//!
//! Findings are structured [`Diagnostic`]s (severity, op/mode/kernel/
//! axis coordinates, counterexample dims when refutable). The same
//! struct backs the context-rich rejection messages of
//! [`DispatchTable::from_data_checked`](crate::dispatch::DispatchTable::from_data_checked)
//! and `runtime::Manifest::load`, and the `vortex audit [--lib
//! dump.json] [--dispatch] [--deny warnings]` CLI (wired into CI)
//! turns the report into an exit code. See the "Static analysis
//! layer" section of `docs/ARCHITECTURE.md`.

use std::fmt;

use crate::coordinator::Selector;
use crate::dispatch::{self, DispatchTable};
use crate::hw::HwSpec;
use crate::ir::{ceil_div, OpKind, OpSpec, Tile};
use crate::runtime::Manifest;
use crate::util::json::Json;

pub mod trace;

pub use trace::audit_trace;

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

/// Finding severity. `Error` refutes an invariant (with a
/// counterexample where one exists); `Warning` flags a condition the
/// audit cannot prove but cannot refute either (e.g. a foreign table
/// payload).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// One structured audit finding. Also the diagnostic currency of the
/// strict loaders ([`crate::dispatch::DispatchTable::from_data_checked`],
/// `runtime::Manifest::load`): every rejection names the offending
/// (op, mode, entry) through the same struct the auditor emits.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable machine-checkable code, e.g. `"dispatch.winner_dominated"`.
    pub code: &'static str,
    pub op: Option<OpKind>,
    /// Mode name (`"adaptive"` / `"only:<backend>"`).
    pub mode: Option<String>,
    /// (library index, kernel index) coordinates.
    pub kernel: Option<(usize, usize)>,
    pub axis: Option<usize>,
    /// Refuting problem dims, when the finding is refutable.
    pub counterexample: Option<Tile>,
    /// Free-form context slot (manifest entry name, payload index, ...).
    pub entry: Option<String>,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            severity: Severity::Error,
            code,
            op: None,
            mode: None,
            kernel: None,
            axis: None,
            counterexample: None,
            entry: None,
            message: message.into(),
        }
    }

    pub fn warning(code: &'static str, message: impl Into<String>) -> Diagnostic {
        Diagnostic { severity: Severity::Warning, ..Diagnostic::error(code, message) }
    }

    pub fn with_op(mut self, op: OpKind) -> Self {
        self.op = Some(op);
        self
    }

    pub fn with_mode(mut self, mode: impl Into<String>) -> Self {
        self.mode = Some(mode.into());
        self
    }

    pub fn with_kernel(mut self, lib: usize, kernel: usize) -> Self {
        self.kernel = Some((lib, kernel));
        self
    }

    pub fn with_axis(mut self, axis: usize) -> Self {
        self.axis = Some(axis);
        self
    }

    pub fn with_counterexample(mut self, dims: Tile) -> Self {
        self.counterexample = Some(dims);
        self
    }

    pub fn with_entry(mut self, entry: impl Into<String>) -> Self {
        self.entry = Some(entry.into());
        self
    }

    /// Structured form of the finding for `vortex audit --json`: every
    /// field of the struct under a stable key, `null` when absent, so
    /// downstream tooling can rely on the shape without probing.
    pub fn to_json(&self) -> Json {
        let opt_str = |s: Option<String>| s.map_or(Json::Null, Json::str);
        Json::obj(vec![
            ("severity", Json::str(self.severity.to_string())),
            ("code", Json::str(self.code)),
            ("op", opt_str(self.op.map(|o| o.to_string()))),
            ("mode", opt_str(self.mode.clone())),
            (
                "kernel",
                self.kernel.map_or(Json::Null, |(l, k)| {
                    Json::arr(vec![Json::num(l as f64), Json::num(k as f64)])
                }),
            ),
            ("axis", self.axis.map_or(Json::Null, |a| Json::num(a as f64))),
            (
                "counterexample",
                self.counterexample.map_or(Json::Null, |dims| {
                    Json::arr(dims.dims().iter().map(|&d| Json::num(d as f64)).collect())
                }),
            ),
            ("entry", opt_str(self.entry.clone())),
            ("message", Json::str(self.message.clone())),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity, self.code)?;
        if let Some(op) = self.op {
            write!(f, " op={op}")?;
        }
        if let Some(mode) = &self.mode {
            write!(f, " mode={mode}")?;
        }
        if let Some((l, k)) = self.kernel {
            write!(f, " kernel=({l},{k})")?;
        }
        if let Some(a) = self.axis {
            write!(f, " axis={a}")?;
        }
        if let Some(dims) = self.counterexample {
            write!(f, " dims={dims}")?;
        }
        if let Some(e) = &self.entry {
            write!(f, " entry={e}")?;
        }
        write!(f, ": {}", self.message)
    }
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// Audit outcome: the findings plus proof-obligation counters (what
/// was actually discharged, so "clean" is distinguishable from
/// "vacuous").
#[derive(Debug, Clone, Default)]
pub struct AuditReport {
    pub diagnostics: Vec<Diagnostic>,
    /// (library, kernel) pairs whose write-set + capacity obligations
    /// were discharged.
    pub kernels_checked: usize,
    /// Per-axis affine segments proven in the write-set pass.
    pub segments_checked: usize,
    /// Fine-lattice cells whose argmin was re-proven in the dispatch
    /// pass.
    pub cells_checked: usize,
    /// (op, mode) dispatch tables audited.
    pub tables_checked: usize,
    /// Trace spans checked in the schema pass ([`audit_trace`]).
    pub spans_checked: usize,
}

impl AuditReport {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// True when the audit gates green: no errors, and no warnings
    /// either when `deny_warnings` (the CI posture).
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.errors() == 0 && (!deny_warnings || self.warnings() == 0)
    }

    /// Fold another report's findings and counters into this one.
    pub fn merge(&mut self, other: AuditReport) {
        self.diagnostics.extend(other.diagnostics);
        self.kernels_checked += other.kernels_checked;
        self.segments_checked += other.segments_checked;
        self.cells_checked += other.cells_checked;
        self.tables_checked += other.tables_checked;
        self.spans_checked += other.spans_checked;
    }

    /// Structured form for `vortex audit --json`: the diagnostics (as
    /// [`Diagnostic::to_json`]) plus the proof-obligation counters and
    /// severity totals, so a pipeline can gate without re-counting.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "diagnostics",
                Json::arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
            ("errors", Json::num(self.errors() as f64)),
            ("warnings", Json::num(self.warnings() as f64)),
            ("kernels_checked", Json::num(self.kernels_checked as f64)),
            ("segments_checked", Json::num(self.segments_checked as f64)),
            ("cells_checked", Json::num(self.cells_checked as f64)),
            ("tables_checked", Json::num(self.tables_checked as f64)),
            ("spans_checked", Json::num(self.spans_checked as f64)),
        ])
    }

    /// One-line human summary of the discharged obligations.
    pub fn summary(&self) -> String {
        format!(
            "{} kernels, {} write-set segments, {} dispatch cells across {} tables: \
             {} errors, {} warnings",
            self.kernels_checked,
            self.segments_checked,
            self.cells_checked,
            self.tables_checked,
            self.errors(),
            self.warnings()
        )
    }
}

/// Auditor configuration: the symbolic horizons of the write-set pass
/// (role-derived like [`crate::dispatch::DispatchConfig`] — the proof
/// covers every shape with all dims inside the horizon box).
#[derive(Debug, Clone)]
pub struct AuditConfig {
    pub horizon: usize,
    pub batch_horizon: usize,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig { horizon: 256, batch_horizon: 32 }
    }
}

impl AuditConfig {
    fn horizons_for(&self, spec: &dyn OpSpec) -> Vec<usize> {
        spec.axes()
            .iter()
            .map(|a| {
                if a.role == crate::ir::AxisRole::Batch {
                    self.batch_horizon
                } else {
                    self.horizon
                }
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// PlanAuditor
// ---------------------------------------------------------------------------

/// The static verification pass: walks a [`Selector`]'s compiled
/// libraries and kernels (and, via [`audit_dispatch_table`], its
/// dispatch tables) and discharges the four invariant families
/// documented in the module docs. Construction is free; every proof
/// obligation runs in [`PlanAuditor::audit`].
pub struct PlanAuditor<'a> {
    selector: &'a Selector,
    manifest: Option<&'a Manifest>,
    cfg: AuditConfig,
}

impl<'a> PlanAuditor<'a> {
    pub fn new(selector: &'a Selector, cfg: AuditConfig) -> Self {
        PlanAuditor { selector, manifest: None, cfg }
    }

    /// Also resolve every kernel's `artifact_name` against an AOT
    /// manifest (real-testbed deployments).
    pub fn with_manifest(mut self, manifest: &'a Manifest) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// Run the write-set, capacity and artifact/alias passes over
    /// every library kernel. Dispatch tables are audited separately
    /// ([`audit_dispatch_table`]) because they are optional payloads.
    pub fn audit(&self) -> AuditReport {
        let mut report = AuditReport::default();
        self.audit_aliases(&mut report);
        for (li, lib) in self.selector.libraries.iter().enumerate() {
            let spec = lib.op.spec();
            let horizons = self.cfg.horizons_for(spec);
            for (ki, k) in lib.kernels.iter().enumerate() {
                report.kernels_checked += 1;
                for d in audit_write_sets(spec, k.l1, &horizons, &mut report.segments_checked)
                {
                    report.diagnostics.push(d.with_op(lib.op).with_kernel(li, ki));
                }
                for d in audit_capacity(&self.selector.hw, spec, lib.dtype.bytes(), k.l0, k.l1)
                {
                    report.diagnostics.push(d.with_op(lib.op).with_kernel(li, ki));
                }
            }
        }
        report
    }

    /// Pass 4: alias fixpoints, dtype agreement, artifact resolution,
    /// embedded payload fingerprints.
    fn audit_aliases(&self, report: &mut AuditReport) {
        for op in OpKind::ALL {
            let spec = op.spec();
            if spec.chain_kernels() == 0 {
                report.diagnostics.push(
                    Diagnostic::error("alias.bad_chain", "chain_kernels() must be >= 1")
                        .with_op(op),
                );
            }
            // The alias chain must reach a fixpoint within |ALL| hops
            // with the iteration-space rank preserved at every hop
            // (aliased measurements re-use the op's own tiles).
            let mut cur = op;
            for hop in 0.. {
                let next = cur.spec().measurement_op();
                if next == cur {
                    break;
                }
                if next.spec().rank() != cur.spec().rank() {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "alias.rank_mismatch",
                            format!(
                                "measurement alias {cur} -> {next} changes rank \
                                 {} -> {}",
                                cur.spec().rank(),
                                next.spec().rank()
                            ),
                        )
                        .with_op(op),
                    );
                    break;
                }
                if hop + 1 >= OpKind::ALL.len() {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "alias.no_fixpoint",
                            format!(
                                "measurement alias chain from {op} has no fixpoint \
                                 within {} hops",
                                OpKind::ALL.len()
                            ),
                        )
                        .with_op(op),
                    );
                    break;
                }
                cur = next;
            }
        }
        let hw = &self.selector.hw;
        for (li, lib) in self.selector.libraries.iter().enumerate() {
            let spec = lib.op.spec();
            for (ki, k) in lib.kernels.iter().enumerate() {
                if k.backend >= hw.backends.len() {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "artifact.bad_backend",
                            format!("backend index {} out of range", k.backend),
                        )
                        .with_op(lib.op)
                        .with_kernel(li, ki),
                    );
                    continue;
                }
                if hw.backends[k.backend].dtype_bytes != lib.dtype.bytes() {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "artifact.dtype_mismatch",
                            format!(
                                "library dtype {} ({}B) vs backend {} ({}B)",
                                lib.dtype,
                                lib.dtype.bytes(),
                                hw.backends[k.backend].name,
                                hw.backends[k.backend].dtype_bytes
                            ),
                        )
                        .with_op(lib.op)
                        .with_kernel(li, ki),
                    );
                }
                if let Some(m) = self.manifest {
                    let name = spec.artifact_name(k.l1, lib.dtype);
                    match m.find(&name) {
                        None => report.diagnostics.push(
                            Diagnostic::error(
                                "artifact.unresolved",
                                format!("artifact {name:?} not in manifest"),
                            )
                            .with_op(lib.op)
                            .with_kernel(li, ki)
                            .with_entry(name),
                        ),
                        Some(e) if e.in_dtype() != lib.dtype => report.diagnostics.push(
                            Diagnostic::error(
                                "artifact.dtype_mismatch",
                                format!(
                                    "artifact {name:?} is {} but the library is {}",
                                    e.in_dtype(),
                                    lib.dtype
                                ),
                            )
                            .with_op(lib.op)
                            .with_kernel(li, ki)
                            .with_entry(name),
                        ),
                        Some(_) => {}
                    }
                }
            }
            // Embedded schema-v3 payloads must adopt cleanly for this
            // selector; a foreign fingerprint is only a warning (the
            // payload would be refused at load, never mis-served).
            if !lib.dispatch.is_empty() {
                if let Err(d) = DispatchTable::from_data_checked(self.selector, &lib.dispatch)
                {
                    let d = if d.code == "load.fingerprint_mismatch" {
                        Diagnostic {
                            severity: Severity::Warning,
                            message: format!(
                                "{} (payload built for a different selector — \
                                 adoption would refuse it)",
                                d.message
                            ),
                            ..d
                        }
                    } else {
                        d
                    };
                    report.diagnostics.push(d.with_entry(format!("library #{li}")));
                }
            }
        }
    }
}

/// Convenience wrapper over [`PlanAuditor`]: audit a selector's
/// libraries (write-sets, capacities, aliases/artifacts).
pub fn audit(selector: &Selector, cfg: &AuditConfig) -> AuditReport {
    PlanAuditor::new(selector, cfg.clone()).audit()
}

// ---------------------------------------------------------------------------
// Pass 1: write-set disjointness + exact cover
// ---------------------------------------------------------------------------

/// Prove one kernel's launch-grid write partition over every output
/// axis, symbolically up to the per-axis horizons. Public so seeded
/// corruption tests can inject a mock [`OpSpec`] with an overlapping
/// footprint and assert the exact diagnostic.
pub fn audit_write_sets(
    spec: &dyn OpSpec,
    l1: Tile,
    horizons: &[usize],
    segments: &mut usize,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (ax, tax) in spec.write_axes() {
        if ax >= spec.rank() || tax >= l1.rank() {
            diags.push(
                Diagnostic::error(
                    "writeset.bad_axis",
                    format!("write_axes maps output axis {ax} to tile axis {tax}"),
                )
                .with_axis(ax),
            );
            continue;
        }
        let extent = l1[tax];
        if extent == 0 {
            diags.push(
                Diagnostic::error("writeset.bad_axis", "zero L1 extent on an output axis")
                    .with_axis(ax),
            );
            continue;
        }
        if let Some(d) = audit_write_axis(spec, extent, horizons[ax], segments) {
            // Lift the per-axis refutation to a full problem shape:
            // the L1 tile with the refuting extent on this axis.
            let mut dims = l1;
            if let Some(bad) = d.counterexample {
                dims[ax] = bad[0];
            }
            diags.push(Diagnostic { counterexample: Some(dims), ..d }.with_axis(ax));
        }
    }
    diags
}

/// Symbolic per-axis proof: split `[1, horizon]` at multiples of
/// `extent`; within one segment the grid `g = ceil(d / extent)` is
/// constant and every footprint is affine in `d`, so both endpoints +
/// non-terminal stability prove the whole segment. Returns the first
/// refutation (counterexample dim in `counterexample[0]`).
fn audit_write_axis(
    spec: &dyn OpSpec,
    extent: usize,
    horizon: usize,
    segments: &mut usize,
) -> Option<Diagnostic> {
    let refute = |code: &'static str, d: usize, msg: String| {
        Some(Diagnostic::error(code, msg).with_counterexample(Tile::new(&[d])))
    };
    let mut prev = 0usize;
    let mut edge = 0usize;
    while edge < horizon.max(1) {
        edge = (edge + extent).min(horizon.max(1));
        *segments += 1;
        let (d_lo, d_hi) = (prev + 1, edge);
        let g = ceil_div(d_hi, extent);
        if ceil_div(d_lo, extent) != g {
            // Unreachable for a multiples-of-extent split; kept so a
            // broken lattice refutes loudly instead of proving nothing.
            return refute(
                "writeset.grid_unstable",
                d_lo,
                format!("grid changes inside segment ({prev}, {edge}]"),
            );
        }
        for d in [d_lo, d_hi] {
            // Partition check at one segment endpoint: intervals chain
            // start-to-end from 0 to d with no gap, overlap, empty
            // in-grid cell, or out-of-bounds write.
            let mut end = 0usize;
            for i in 0..g {
                let (s, t) = spec.write_footprint(d, extent, i);
                if t > d {
                    return refute(
                        "writeset.out_of_bounds",
                        d,
                        format!("cell {i} writes [{s}, {t}) past the output edge {d}"),
                    );
                }
                if s < end {
                    return refute(
                        "writeset.overlap",
                        d,
                        format!("cell {i} writes [{s}, {t}) overlapping [0, {end})"),
                    );
                }
                if s > end {
                    return refute(
                        "writeset.gap",
                        d,
                        format!("cell {i} writes [{s}, {t}) leaving [{end}, {s}) uncovered"),
                    );
                }
                if t <= s {
                    return refute(
                        "writeset.gap",
                        d,
                        format!("in-grid cell {i} of {g} writes nothing"),
                    );
                }
                end = t;
            }
            if end != d {
                return refute(
                    "writeset.gap",
                    d,
                    format!("grid covers [0, {end}) of [0, {d})"),
                );
            }
            // Beyond-grid cells (the batched path's batch-edge break)
            // must write nothing.
            let (s, t) = spec.write_footprint(d, extent, g);
            if t > s {
                return refute(
                    "writeset.overlap",
                    d,
                    format!("beyond-grid cell {g} writes [{s}, {t})"),
                );
            }
        }
        // Affine-segment stability: non-terminal footprints must not
        // depend on d inside the segment (the terminal cell's end is
        // pinned to d by the endpoint checks above).
        for i in 0..g.saturating_sub(1) {
            if spec.write_footprint(d_lo, extent, i) != spec.write_footprint(d_hi, extent, i) {
                return refute(
                    "writeset.grid_unstable",
                    d_lo,
                    format!("non-terminal cell {i} footprint varies inside ({prev}, {edge}]"),
                );
            }
        }
        prev = edge;
    }
    None
}

// ---------------------------------------------------------------------------
// Pass 2: capacity bounds at closed-form extrema
// ---------------------------------------------------------------------------

/// Prove one kernel's working sets fit the L0/L1 capacities for every
/// admissible shape: one `working_set` evaluation at the per-axis
/// extrema corner per level (monotonicity makes the corner the
/// supremum), plus the L0-per-L1 concurrency bound.
pub fn audit_capacity(
    hw: &HwSpec,
    spec: &dyn OpSpec,
    dtype_bytes: usize,
    l0: Tile,
    l1: Tile,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (level, tile, code) in
        [(0usize, l0, "capacity.l0_exceeded"), (1, l1, "capacity.l1_exceeded")]
    {
        let corner = spec.axis_extrema(tile);
        let ws = spec.working_set(corner, dtype_bytes);
        let cap = hw.level(level).capacity_bytes;
        if ws > cap {
            diags.push(
                Diagnostic::error(
                    code,
                    format!(
                        "working set {ws}B at the extrema corner exceeds L{level} \
                         capacity {cap}B ({})",
                        hw.level(level).name
                    ),
                )
                .with_counterexample(corner),
            );
        }
    }
    let conc = spec.spatial_iters(l1, l0);
    if conc > hw.max_l0_per_l1 as usize {
        diags.push(
            Diagnostic::error(
                "capacity.concurrency",
                format!(
                    "{conc} parallel L0 tiles per L1 unit exceed the hardware \
                     bound {}",
                    hw.max_l0_per_l1
                ),
            )
            .with_counterexample(l1),
        );
    }
    diags
}

// ---------------------------------------------------------------------------
// Pass 3: dispatch-table region soundness
// ---------------------------------------------------------------------------

/// Cap on per-table findings so one systemic corruption doesn't flood
/// the report with thousands of per-cell repeats.
const MAX_TABLE_DIAGS: usize = 8;

/// Prove every cell of every (op, mode) table serves the first strict
/// argmin of the eligible fast-path scan — the machine-checked version
/// of the dispatch layer's "provably identical to fresh selection"
/// claim. See the module docs for why one representative per fine
/// cell is a proof rather than a sample.
pub fn audit_dispatch_table(selector: &Selector, table: &DispatchTable) -> AuditReport {
    let mut report = AuditReport::default();
    if !table.matches(selector) {
        report.diagnostics.push(Diagnostic::error(
            "dispatch.fingerprint_mismatch",
            "table was built for a different selector (hardware spec or library set)",
        ));
        return report;
    }
    for t in &table.tables {
        report.tables_checked += 1;
        audit_op_table(selector, t, &mut report);
    }
    report
}

fn audit_op_table(selector: &Selector, t: &dispatch::OpTable, report: &mut AuditReport) {
    let op = t.op;
    let mode = t.mode;
    let mode_name = dispatch::mode_name(mode);
    let diag = |d: Diagnostic| d.with_op(op).with_mode(&mode_name);
    let serving = selector.serving_op(op);
    let chain = selector.chain_factor(op);
    let eligible = selector.eligible_fast(serving, mode);
    if eligible.is_empty() {
        report.diagnostics.push(diag(Diagnostic::error(
            "dispatch.no_kernels",
            "table exists but no fast-path kernel serves this (op, mode)",
        )));
        return;
    }
    let rank = op.spec().rank();
    if t.edges.len() != rank {
        report.diagnostics.push(diag(Diagnostic::error(
            "dispatch.bad_edges",
            format!("{} edge axes for a rank-{rank} op", t.edges.len()),
        )));
        return;
    }
    // The fine lattice: every eligible L1-extent multiple up to the
    // table's own effective horizon, per axis. Between consecutive
    // fine edges no eligible kernel's launch grid can change, so the
    // argmin is constant — one representative per fine cell is exact.
    let mut fine: Vec<Vec<usize>> = Vec::with_capacity(rank);
    let mut off_lattice = false;
    for a in 0..rank {
        let te = &t.edges[a];
        if te.is_empty() || te.windows(2).any(|w| w[0] >= w[1]) {
            report.diagnostics.push(
                diag(Diagnostic::error(
                    "dispatch.bad_edges",
                    "empty or non-increasing edge vector",
                ))
                .with_axis(a),
            );
            return;
        }
        let horizon = *te.last().unwrap();
        let mut extents: Vec<usize> = Vec::new();
        for &fi in &eligible {
            let e = selector.fast[fi].l1[a];
            if !extents.contains(&e) {
                extents.push(e);
            }
        }
        let f = dispatch::axis_edges(&extents, horizon);
        // Every stored (merged) edge must lie ON the fine lattice:
        // region merging keeps a run's last fine edge, so an off-
        // lattice edge can only come from tampering — and it would
        // split a grid-constant segment, making lookups shape-
        // dependent inside one cell.
        for &edge in te {
            if f.binary_search(&edge).is_err() {
                off_lattice = true;
                report.diagnostics.push(
                    diag(Diagnostic::error(
                        "dispatch.edge_off_lattice",
                        format!(
                            "stored edge {edge} is not an eligible L1-extent \
                             multiple (or the horizon)"
                        ),
                    ))
                    .with_axis(a),
                );
            }
        }
        fine.push(f);
    }
    if off_lattice {
        return; // winner lookups inside a split segment are meaningless
    }
    // Exhaustive fine-cell pass: representative dims = per-axis upper
    // edges; recompute the first strict argmin with the scan's exact
    // arithmetic, order and tie-break; compare with the table lookup.
    let n_cells: usize = fine.iter().map(Vec::len).product();
    let mut digits = vec![0usize; rank];
    let mut table_diags = 0usize;
    for _ in 0..n_cells {
        report.cells_checked += 1;
        let mut rep = Tile::ones(rank);
        for a in 0..rank {
            rep[a] = fine[a][digits[a]];
        }
        let mut best = f64::INFINITY;
        let mut best_fi = eligible[0];
        for &fi in &eligible {
            let secs = selector.fast[fi].estimate(rep).0 * chain;
            if secs < best {
                best = secs;
                best_fi = fi;
            }
        }
        // Table lookup at the representative (same binary search as
        // `DispatchTable::select`).
        let mut flat = 0usize;
        let mut covered = true;
        for a in 0..rank {
            let idx = t.edges[a].partition_point(|&edge| edge < rep[a]);
            if idx == t.edges[a].len() {
                covered = false;
                break;
            }
            flat = flat * t.edges[a].len() + idx;
        }
        if !covered {
            report.diagnostics.push(
                diag(Diagnostic::error(
                    "dispatch.coverage_gap",
                    "in-horizon representative not covered by the stored edges",
                ))
                .with_counterexample(rep),
            );
            return;
        }
        let stored = t.winners[flat] as usize;
        if stored != best_fi && table_diags < MAX_TABLE_DIAGS {
            let fk = selector.fast.get(stored);
            let d = match fk {
                None => diag(Diagnostic::error(
                    "dispatch.winner_ineligible",
                    format!("winner index {stored} out of fast-path range"),
                )),
                Some(fk) if !eligible.contains(&stored) => diag(Diagnostic::error(
                    "dispatch.winner_ineligible",
                    format!("winner (lib {}, kernel {}) cannot serve this (op, mode)", fk.lib, fk.kernel),
                ))
                .with_kernel(fk.lib, fk.kernel),
                Some(fk) => {
                    let secs = fk.estimate(rep).0 * chain;
                    if secs > best {
                        diag(Diagnostic::error(
                            "dispatch.winner_dominated",
                            format!(
                                "stored winner estimates {secs:.3e}s but (lib {}, \
                                 kernel {}) estimates {best:.3e}s across this cell",
                                selector.fast[best_fi].lib, selector.fast[best_fi].kernel
                            ),
                        ))
                        .with_kernel(fk.lib, fk.kernel)
                    } else {
                        diag(Diagnostic::error(
                            "dispatch.tie_break",
                            format!(
                                "stored winner ties the argmin but is not the scan's \
                                 FIRST argmin (lib {}, kernel {})",
                                selector.fast[best_fi].lib, selector.fast[best_fi].kernel
                            ),
                        ))
                        .with_kernel(fk.lib, fk.kernel)
                    }
                }
            };
            report.diagnostics.push(d.with_counterexample(rep));
            table_diags += 1;
        }
        for a in (0..rank).rev() {
            digits[a] += 1;
            if digits[a] < fine[a].len() {
                break;
            }
            digits[a] = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// SLO feasibility audit
// ---------------------------------------------------------------------------

/// Static SLO feasibility audit: check every lane deadline in a
/// [`ServeConfig`](crate::serve::ServeConfig) against the modeled
/// service FLOOR — the same closed-form estimates selection runs on,
/// evaluated at the smallest possible problem (all-ones dims), so the
/// verdict is sample-free like everything else in this layer. Codes:
///
/// * `slo.nonpositive_deadline` (error) — a deadline <= 0 can never be
///   met by any request.
/// * `slo.unservable_mode` (error) — the lane's mode (or its overload
///   DOWNGRADE mode) admits no fast-path kernel for some op the lane
///   serves: under overload, selection would have nothing to pick.
/// * `slo.infeasible_deadline` (error) — the deadline is below
///   `SCHED_OVERHEAD_SECS + min_kernel chain × estimate(ones)`: even
///   the smallest conceivable request on the best eligible kernel
///   cannot finish in time, so EVERY admission decision the policy
///   makes is forced.
/// * `slo.window_exceeds_deadline` (warning) — the configured static
///   batch window alone is at least the whole deadline. Serving caps
///   the effective window at the deadline budget
///   ([`crate::serve::LaneSlo::window`]), so this is survivable — but
///   the configuration is self-contradictory and worth flagging.
///
/// Lanes without a deadline are skipped: no SLO, no obligations.
/// [`crate::serve::serve_fleet`] runs this before serving and reports
/// the findings in `FleetStats::slo_diags` (advisory, not a refusal —
/// the overload policy still does something well-defined).
pub fn audit_slo(selector: &Selector, cfg: &crate::serve::ServeConfig) -> AuditReport {
    use crate::serve::{LaneClass, OverloadPolicy, SCHED_OVERHEAD_SECS};
    let mut report = AuditReport::default();
    for class in LaneClass::ALL {
        let lane = cfg.lane(class);
        let Some(deadline) = lane.slo.deadline else { continue };
        if deadline <= 0.0 {
            report.diagnostics.push(Diagnostic::error(
                "slo.nonpositive_deadline",
                format!("{} lane: deadline {deadline:.3e}s is not positive", class.name()),
            ));
            continue;
        }
        if lane.batch_window >= deadline {
            report.diagnostics.push(Diagnostic::warning(
                "slo.window_exceeds_deadline",
                format!(
                    "{} lane: configured batch window {:.3e}s >= deadline {deadline:.3e}s \
                     (the effective window is capped at the deadline budget)",
                    class.name(),
                    lane.batch_window,
                ),
            ));
        }
        // The lane must be servable — and its deadline meetable —
        // under its configured mode AND under the overload downgrade
        // mode, if one is set: the downgrade path only runs when the
        // lane is already in trouble.
        let mut modes = vec![lane.mode];
        if let OverloadPolicy::Degrade(m) = lane.slo.policy {
            if m != lane.mode {
                modes.push(m);
            }
        }
        for mode in modes {
            let mode_name = dispatch::mode_name(mode);
            for &op in class.ops() {
                let serving = selector.serving_op(op);
                let eligible = selector.eligible_fast(serving, mode);
                if eligible.is_empty() {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "slo.unservable_mode",
                            format!(
                                "{} lane: no fast-path kernel serves {op:?} under this \
                                 mode — selection would have nothing to pick",
                                class.name(),
                            ),
                        )
                        .with_op(op)
                        .with_mode(&mode_name),
                    );
                    continue;
                }
                report.kernels_checked += eligible.len();
                let chain = selector.chain_factor(op);
                let ones = Tile::ones(serving.spec().rank());
                let floor = SCHED_OVERHEAD_SECS
                    + eligible
                        .iter()
                        .map(|&fi| chain * selector.fast[fi].estimate(ones).0)
                        .fold(f64::INFINITY, f64::min);
                if deadline < floor {
                    report.diagnostics.push(
                        Diagnostic::error(
                            "slo.infeasible_deadline",
                            format!(
                                "{} lane: deadline {deadline:.3e}s is below the modeled \
                                 service floor {floor:.3e}s for {op:?} (smallest problem, \
                                 best eligible kernel) — no request can ever meet it",
                                class.name(),
                            ),
                        )
                        .with_op(op)
                        .with_mode(&mode_name)
                        .with_counterexample(ones),
                    );
                }
            }
        }
    }
    report
}

#[cfg(test)]
mod tests;
