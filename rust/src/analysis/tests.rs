use super::*;
use crate::compiler::{compile, CompileOpts};
use crate::coordinator::HwMode;
use crate::cost::hybrid::AnalyzerConfig;
use crate::dispatch::DispatchConfig;
use crate::hw::presets;
use crate::ir::{Axis, DType};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;

fn selector(seed: u64) -> Selector {
    let hw = presets::a100();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let libs = vec![
        compile(&hw, OpKind::Gemm, DType::F32, &cfg, &mut prof, &CompileOpts::default())
            .library,
        compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut prof, &CompileOpts::default())
            .library,
        compile(&hw, OpKind::BatchedGemm, DType::F16, &cfg, &mut prof, &CompileOpts::default())
            .library,
    ];
    Selector::new(hw, libs)
}

fn dispatch_config() -> DispatchConfig {
    DispatchConfig {
        horizon: 48,
        batch_horizon: 6,
        modes: vec![HwMode::Adaptive, HwMode::Only("cuda_core_f32")],
        max_cells: 1 << 14,
        ..DispatchConfig::default()
    }
}

#[test]
fn clean_selector_audits_clean() {
    let s = selector(11);
    let report = audit(&s, &AuditConfig::default());
    assert!(
        report.diagnostics.is_empty(),
        "expected a clean audit, got: {:?}",
        report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
    assert!(report.kernels_checked > 0);
    assert!(report.segments_checked > 0);
}

#[test]
fn clean_dispatch_table_audits_clean() {
    let s = selector(11);
    let table = DispatchTable::for_selector(&s, &dispatch_config());
    let report = audit_dispatch_table(&s, &table);
    assert!(
        report.diagnostics.is_empty(),
        "expected a clean table audit, got: {:?}",
        report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
    assert_eq!(report.tables_checked, table.stats.tables);
    assert!(report.cells_checked > 0);
}

#[test]
fn foreign_table_is_fingerprint_mismatch() {
    let s = selector(11);
    // A selector over a strictly smaller library set: the fingerprint
    // hashes every library's identity, so this is provably foreign.
    let other = Selector::new(s.hw.clone(), s.libraries[..1].to_vec());
    let table = DispatchTable::for_selector(&other, &dispatch_config());
    let report = audit_dispatch_table(&s, &table);
    assert_eq!(report.errors(), 1);
    assert_eq!(report.diagnostics[0].code, "dispatch.fingerprint_mismatch");
}

/// Satellite: a tampered interval edge is named as exactly the
/// off-lattice diagnostic (the tamper target is chosen off the same
/// fine lattice the auditor derives, so the test is deterministic).
#[test]
fn tampered_edge_is_caught_off_lattice() {
    let s = selector(11);
    let mut table = DispatchTable::for_selector(&s, &dispatch_config());
    let mut tampered = false;
    'search: for t in &mut table.tables {
        let eligible = s.eligible_fast(s.serving_op(t.op), t.mode);
        for a in 0..t.edges.len() {
            let horizon = *t.edges[a].last().unwrap();
            let mut extents: Vec<usize> = Vec::new();
            for &fi in &eligible {
                let e = s.fast[fi].l1[a];
                if !extents.contains(&e) {
                    extents.push(e);
                }
            }
            let fine = crate::dispatch::axis_edges(&extents, horizon);
            // A non-terminal edge whose successor is off the lattice:
            // bumping it by one cannot collide with the next stored
            // edge (stored edges are a subset of the lattice).
            for j in 0..t.edges[a].len().saturating_sub(1) {
                let bumped = t.edges[a][j] + 1;
                if fine.binary_search(&bumped).is_err() && bumped < t.edges[a][j + 1] {
                    t.edges[a][j] = bumped;
                    tampered = true;
                    break 'search;
                }
            }
        }
    }
    assert!(tampered, "no tamperable off-lattice edge found in any table");
    let report = audit_dispatch_table(&s, &table);
    assert!(report.errors() > 0);
    assert!(
        report.diagnostics.iter().any(|d| d.code == "dispatch.edge_off_lattice"),
        "expected dispatch.edge_off_lattice, got: {:?}",
        report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
}

/// Satellite: a winner swapped inside a merged region is refuted with
/// the dominated diagnostic and a counterexample shape.
#[test]
fn swapped_winner_is_caught_dominated() {
    let s = selector(11);
    let mut table = DispatchTable::for_selector(&s, &dispatch_config());
    let mut tampered = false;
    'search: for t in &mut table.tables {
        let serving = s.serving_op(t.op);
        let chain = s.chain_factor(t.op);
        let eligible = s.eligible_fast(serving, t.mode);
        if eligible.len() < 2 {
            continue;
        }
        let rank = t.edges.len();
        let n_cells: usize = t.edges.iter().map(Vec::len).product();
        for flat in 0..n_cells {
            // Representative of this merged cell: its per-axis upper
            // edges (which are fine-lattice edges, so the auditor is
            // guaranteed to evaluate there).
            let mut rem = flat;
            let mut rep = Tile::ones(rank);
            for a in (0..rank).rev() {
                rep[a] = t.edges[a][rem % t.edges[a].len()];
                rem /= t.edges[a].len();
            }
            let best = eligible
                .iter()
                .map(|&fi| s.fast[fi].estimate(rep).0 * chain)
                .fold(f64::INFINITY, f64::min);
            // A strictly-dominated rival at this representative.
            if let Some(&rival) = eligible
                .iter()
                .find(|&&fi| s.fast[fi].estimate(rep).0 * chain > best)
            {
                t.winners[flat] = rival as u32;
                tampered = true;
                break 'search;
            }
        }
    }
    assert!(tampered, "no cell with a strictly-dominated rival found");
    let report = audit_dispatch_table(&s, &table);
    assert!(report.errors() > 0);
    let hit = report
        .diagnostics
        .iter()
        .find(|d| d.code == "dispatch.winner_dominated")
        .unwrap_or_else(|| {
            panic!(
                "expected dispatch.winner_dominated, got: {:?}",
                report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
            )
        });
    assert!(hit.counterexample.is_some(), "refutation must carry a counterexample shape");
}

/// Satellite: the dispatch pass covers the decode lane's op on EVERY
/// hardware preset — CausalAttention gets a table (through the
/// batched-GEMM alias) on each grid, and that table's masked-traffic
/// argmin proof discharges cleanly.
#[test]
fn causal_attention_is_audited_on_every_preset_grid() {
    for hw in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 11));
        let opts = CompileOpts::default();
        let lib = compile(&hw, OpKind::BatchedGemm, DType::F32, &cfg, &mut prof, &opts).library;
        let s = Selector::new(hw.clone(), vec![lib]);
        let dcfg = DispatchConfig {
            horizon: 48,
            batch_horizon: 6,
            max_cells: 1 << 14,
            ..DispatchConfig::default()
        };
        let table = DispatchTable::for_selector(&s, &dcfg);
        assert!(
            table.tables.iter().any(|t| t.op == OpKind::CausalAttention),
            "{}: no CausalAttention table in the preset grid",
            s.hw.name
        );
        let report = audit_dispatch_table(&s, &table);
        assert!(
            report.is_clean(true),
            "{}: CausalAttention grid audit found problems: {:?}",
            s.hw.name,
            report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        );
        assert_eq!(report.tables_checked, table.tables.len());
    }
}

/// Satellite: a tampered winner inside the CausalAttention table — the
/// masked-traffic argmin the decode lane trusts for its zero-scan
/// steady state — is refuted by the named dominance diagnostic, with
/// the finding carrying the op and a counterexample shape.
#[test]
fn tampered_causal_decode_winner_is_caught() {
    let s = selector(11);
    let mut table = DispatchTable::for_selector(&s, &dispatch_config());
    let mut tampered = false;
    'search: for t in &mut table.tables {
        if t.op != OpKind::CausalAttention {
            continue;
        }
        let chain = s.chain_factor(t.op);
        let eligible = s.eligible_fast(s.serving_op(t.op), t.mode);
        if eligible.len() < 2 {
            continue;
        }
        let rank = t.edges.len();
        let n_cells: usize = t.edges.iter().map(Vec::len).product();
        for flat in 0..n_cells {
            let mut rem = flat;
            let mut rep = Tile::ones(rank);
            for a in (0..rank).rev() {
                rep[a] = t.edges[a][rem % t.edges[a].len()];
                rem /= t.edges[a].len();
            }
            let best = eligible
                .iter()
                .map(|&fi| s.fast[fi].estimate(rep).0 * chain)
                .fold(f64::INFINITY, f64::min);
            if let Some(&rival) = eligible
                .iter()
                .find(|&&fi| s.fast[fi].estimate(rep).0 * chain > best)
            {
                t.winners[flat] = rival as u32;
                tampered = true;
                break 'search;
            }
        }
    }
    assert!(tampered, "no CausalAttention cell with a strictly-dominated rival");
    let report = audit_dispatch_table(&s, &table);
    assert!(report.errors() > 0);
    let hit = report
        .diagnostics
        .iter()
        .find(|d| d.code == "dispatch.winner_dominated")
        .unwrap_or_else(|| {
            panic!(
                "expected dispatch.winner_dominated, got: {:?}",
                report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
            )
        });
    assert_eq!(hit.op, Some(OpKind::CausalAttention), "finding must name the decode op");
    assert!(hit.counterexample.is_some(), "refutation must carry a counterexample shape");
}

/// Satellite: an undersized capacity is named per level, with the
/// extrema corner as the counterexample.
#[test]
fn undersized_capacity_is_caught() {
    let mut s = selector(11);
    s.hw.levels[1].capacity_bytes = 1;
    let report = audit(&s, &AuditConfig::default());
    assert!(report.errors() > 0);
    assert!(
        report.diagnostics.iter().any(|d| d.code == "capacity.l1_exceeded"),
        "expected capacity.l1_exceeded, got: {:?}",
        report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
    // Every capacity refutation names the (lib, kernel) coordinates.
    assert!(report
        .diagnostics
        .iter()
        .filter(|d| d.code == "capacity.l1_exceeded")
        .all(|d| d.kernel.is_some() && d.counterexample.is_some()));
}

/// A mock op whose grid cells each write one element too far — the
/// runtime scatter bug the write-set pass exists to refute.
struct OverlappingWrites;

impl OpSpec for OverlappingWrites {
    fn name(&self) -> &'static str {
        "mock_overlap"
    }
    fn kind(&self) -> OpKind {
        OpKind::Gemm
    }
    fn axes(&self) -> &'static [Axis] {
        OpKind::Gemm.spec().axes()
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        OpKind::Gemm.spec().working_set(tile, in_bytes)
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        OpKind::Gemm.spec().min_bytes(iter, dtype)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        OpKind::Gemm.spec().load_bytes_per_step(parent, child, dtype)
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        OpKind::Gemm.spec().store_bytes(parent)
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        OpKind::Gemm.spec().artifact_name(l1, dtype)
    }
    fn write_footprint(&self, d: usize, e: usize, i: usize) -> (usize, usize) {
        // One element of overlap into the next cell's region.
        ((i * e).min(d), ((i + 1) * e + 1).min(d))
    }
}

/// A mock op whose terminal cell stops one element short of the edge.
struct GappedWrites;

impl OpSpec for GappedWrites {
    fn name(&self) -> &'static str {
        "mock_gap"
    }
    fn kind(&self) -> OpKind {
        OpKind::Gemm
    }
    fn axes(&self) -> &'static [Axis] {
        OpKind::Gemm.spec().axes()
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        OpKind::Gemm.spec().working_set(tile, in_bytes)
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        OpKind::Gemm.spec().min_bytes(iter, dtype)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        OpKind::Gemm.spec().load_bytes_per_step(parent, child, dtype)
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        OpKind::Gemm.spec().store_bytes(parent)
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        OpKind::Gemm.spec().artifact_name(l1, dtype)
    }
    fn write_footprint(&self, d: usize, e: usize, i: usize) -> (usize, usize) {
        // Edge cropping off by one: the terminal cell misses the last
        // output element whenever d is not a tile multiple.
        ((i * e).min(d), ((i + 1) * e).min(d.saturating_sub(d % e)).max((i * e).min(d)))
    }
}

/// Satellite: an overlapping write-set injected via a mock `OpSpec` is
/// refuted with the exact overlap diagnostic (and the gap twin with
/// the gap diagnostic) — the clean default passes untouched.
#[test]
fn mock_write_footprints_are_refuted() {
    let l1 = Tile::new(&[8, 8, 16]);
    let horizons = [48usize, 48, 48];
    let mut segs = 0usize;

    let clean = audit_write_sets(OpKind::Gemm.spec(), l1, &horizons, &mut segs);
    assert!(clean.is_empty(), "default footprint must prove clean: {clean:?}");
    assert!(segs > 0);

    let overlap = audit_write_sets(&OverlappingWrites, l1, &horizons, &mut segs);
    assert!(
        overlap.iter().any(|d| d.code == "writeset.overlap"),
        "expected writeset.overlap, got: {:?}",
        overlap.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
    assert!(overlap.iter().all(|d| d.counterexample.is_some()));

    let gap = audit_write_sets(&GappedWrites, l1, &horizons, &mut segs);
    assert!(
        gap.iter().any(|d| d.code == "writeset.gap"),
        "expected writeset.gap, got: {:?}",
        gap.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
}

/// Satellite: strict-loader rejections carry the (op, mode, entry)
/// context through the shared diagnostic struct.
#[test]
fn loader_diagnostics_name_the_offender() {
    let s = selector(11);
    let table = DispatchTable::for_selector(&s, &dispatch_config());
    let mut data = table.to_data(&s);

    // Tampered content → digest mismatch naming the table.
    data[0].edges[0][0] += 1;
    let err = DispatchTable::from_data_checked(&s, &data).unwrap_err();
    assert_eq!(err.code, "load.digest_mismatch");
    assert_eq!(err.op, Some(data[0].op));
    assert!(err.entry.as_deref() == Some("table #0"));

    // Foreign fingerprint → named as such (and `from_data` still
    // answers None, the PR 5 contract).
    let mut foreign = table.to_data(&s);
    for d in &mut foreign {
        d.fingerprint ^= 1;
    }
    let err = DispatchTable::from_data_checked(&s, &foreign).unwrap_err();
    assert_eq!(err.code, "load.fingerprint_mismatch");
    assert!(DispatchTable::from_data(&s, &foreign).is_none());
}

/// Aliases of the shipped ops reach their fixpoints; the audit's
/// alias pass proves it for every op (not just the compiled ones).
#[test]
fn alias_pass_covers_every_op() {
    let s = selector(11);
    let report = audit(&s, &AuditConfig::default());
    assert!(report.diagnostics.iter().all(|d| !d.code.starts_with("alias.")));
}

// ---------------------------------------------------------------------------
// SLO feasibility audit
// ---------------------------------------------------------------------------

mod slo_audit {
    use super::*;
    use crate::serve::{LaneClass, LaneSlo, OverloadPolicy, ServeConfig};

    #[test]
    fn no_deadlines_audit_vacuously_clean() {
        let s = selector(11);
        let report = audit_slo(&s, &ServeConfig::default());
        assert!(report.diagnostics.is_empty());
    }

    #[test]
    fn generous_deadline_is_feasible() {
        let s = selector(11);
        let mut cfg = ServeConfig::default();
        cfg.lane_mut(LaneClass::Gemm).slo = LaneSlo::with_deadline(1.0);
        let report = audit_slo(&s, &cfg);
        assert!(
            report.diagnostics.is_empty(),
            "{:?}",
            report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        );
        assert!(report.kernels_checked > 0);
    }

    #[test]
    fn deadline_below_the_service_floor_is_an_error() {
        let s = selector(11);
        let mut cfg = ServeConfig::default();
        // 1 ps: far below SCHED_OVERHEAD_SECS alone, let alone the
        // smallest kernel estimate — provably unmeetable.
        cfg.lane_mut(LaneClass::Gemm).slo = LaneSlo::with_deadline(1e-12);
        let report = audit_slo(&s, &cfg);
        assert!(report.errors() >= 1);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == "slo.infeasible_deadline" && d.op == Some(OpKind::Gemm)));
    }

    #[test]
    fn nonpositive_deadline_is_an_error() {
        let s = selector(11);
        let mut cfg = ServeConfig::default();
        cfg.lane_mut(LaneClass::Gemm).slo = LaneSlo::with_deadline(0.0);
        let report = audit_slo(&s, &cfg);
        assert_eq!(report.errors(), 1);
        assert_eq!(report.diagnostics[0].code, "slo.nonpositive_deadline");
    }

    #[test]
    fn unservable_degrade_mode_is_an_error() {
        let s = selector(11);
        let mut cfg = ServeConfig::default();
        // No backend named "nonexistent" exists on the A100 preset:
        // the downgrade path would leave selection with nothing.
        cfg.lane_mut(LaneClass::Gemm).slo = LaneSlo::with_deadline(1.0)
            .with_policy(OverloadPolicy::Degrade(HwMode::Only("nonexistent")));
        let report = audit_slo(&s, &cfg);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == "slo.unservable_mode"));
        // A real backend as the downgrade mode audits clean.
        cfg.lane_mut(LaneClass::Gemm).slo = LaneSlo::with_deadline(1.0)
            .with_policy(OverloadPolicy::Degrade(HwMode::Only("cuda_core_f32")));
        assert!(audit_slo(&s, &cfg).diagnostics.is_empty());
    }

    #[test]
    fn window_at_or_past_the_deadline_warns() {
        let s = selector(11);
        let mut cfg = ServeConfig::default();
        let lane = cfg.lane_mut(LaneClass::Gemm);
        lane.slo = LaneSlo::with_deadline(1e-3);
        lane.batch_window = 5e-3;
        let report = audit_slo(&s, &cfg);
        assert_eq!(report.errors(), 0);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.code == "slo.window_exceeds_deadline"));
    }
}
