//! Vortex offline compilation pipeline (paper §5, Fig. 6 left).
//!
//! `compile()` runs the full offline stage for one (hardware, dtype)
//! pair:
//!
//! 1. bottom-up candidate generation ([`crate::candgen`], Algorithm 2);
//! 2. per-candidate strategy analysis with the hybrid analyzer
//!    ([`crate::cost::hybrid`]) — the best child mapping is chosen for
//!    every level-1 candidate and the subchain cost is recorded;
//! 3. pruning to a compact [`MicroKernelLibrary`] (near-duplicate tiles
//!    are bucketed by log-shape and only the most efficient survivor of
//!    each bucket is kept), so runtime selection stays microseconds.
//!
//! The library is the *only* artifact the runtime stage needs — no shape
//! samples anywhere (the paper's headline property).

use std::collections::HashMap;
use std::time::Instant;

use crate::candgen;
use crate::cost::hybrid::{hybrid_cost, AnalyzerConfig};
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::DType;
use crate::profiler::Profiler;
use crate::util::json::Json;

/// One compiled micro-kernel: an (L0, L1) tile chain with its measured /
/// estimated subchain cost (one L1 block's execution on one unit).
#[derive(Debug, Clone, PartialEq)]
pub struct MicroKernel {
    pub l0: [usize; 3],
    pub l1: [usize; 3],
    pub backend: usize,
    /// Cost of the [l0, l1] subchain, seconds (hybrid analyzer output).
    pub base_cost: f64,
}

impl MicroKernel {
    pub fn flops(&self) -> f64 {
        2.0 * self.l1.iter().map(|&d| d as f64).product::<f64>()
    }

    /// Throughput of the block itself, GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.flops() / self.base_cost / 1e9
    }

    /// The runtime strategy chain for a padded problem shape.
    pub fn chain(&self, padded: [usize; 3]) -> Strategy {
        Strategy::new(vec![self.l0, self.l1, padded], self.backend)
    }

    /// Artifact name convention shared with python/compile/aot.py.
    pub fn artifact_name(&self, dtype: DType) -> String {
        format!(
            "gemm_acc_{}x{}x{}_{}",
            self.l1[0], self.l1[1], self.l1[2], dtype.name()
        )
    }
}

/// The offline output: a compact set of micro-kernels + bookkeeping.
#[derive(Debug, Clone)]
pub struct MicroKernelLibrary {
    pub hw_name: String,
    pub dtype: DType,
    pub analyzer: AnalyzerConfig,
    pub kernels: Vec<MicroKernel>,
}

/// Offline statistics (paper §7.4 offline-overhead analysis).
#[derive(Debug, Clone)]
pub struct CompileReport {
    pub library: MicroKernelLibrary,
    /// Total candidates generated (Algorithm 2), both levels.
    pub candidates_total: usize,
    /// (L1, child) chains analyzed.
    pub chains_analyzed: usize,
    /// Profiling queries issued.
    pub profile_queries: usize,
    /// Modeled offline wall-clock on the target hardware: candgen +
    /// analysis (measured here) + profiling tuning time (modeled).
    pub offline_secs: f64,
    /// Actual wall-clock spent in this process.
    pub wall_secs: f64,
}

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct CompileOpts {
    /// Keep only the best kernel per log-shape bucket.
    pub prune: bool,
    /// Profile every (L1, child) pair instead of only the analytically
    /// best child — Table 7's expensive "Changed" configuration.
    pub profile_all_pairs: bool,
    /// Restrict the library to these L1 tiles (used on the real testbed
    /// to match the AOT artifact set). Empty = no restriction.
    pub restrict_l1: Vec<[usize; 3]>,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts { prune: true, profile_all_pairs: false, restrict_l1: Vec::new() }
    }
}

fn log_bucket(tile: [usize; 3]) -> [u32; 3] {
    [
        (tile[0] as f64).log2().round() as u32,
        (tile[1] as f64).log2().round() as u32,
        (tile[2] as f64).log2().round() as u32,
    ]
}

/// Run the offline stage.
pub fn compile(
    hw: &HwSpec,
    dtype: DType,
    cfg: &AnalyzerConfig,
    profiler: &mut dyn Profiler,
    opts: &CompileOpts,
) -> CompileReport {
    let wall0 = Instant::now();
    let queries0 = profiler.queries();
    let tuning0 = profiler.tuning_secs();

    // 1. Algorithm 2.
    let set = candgen::generate(hw, dtype);
    let candidates_total = set.total();

    // 2. Strategy analysis: best child per L1 candidate. Children are
    // RANKED with at most L0-empirical splicing (distinct L0 tiles are
    // few, so this is cheap); only the WINNING pair is then profiled at
    // the configured fidelity — this is what keeps the paper's offline
    // query counts at ~(#L0 + #L1) instead of #chains. The
    // `profile_all_pairs` flag (Table 7 "Changed") measures every pair.
    let rank_cfg = AnalyzerConfig {
        empirical_up_to: cfg.empirical_up_to.map(|e| e.min(0)),
    };
    let mut kernels: Vec<MicroKernel> = Vec::new();
    let mut chains = 0usize;
    for (i, l1) in set.levels[1].iter().enumerate() {
        if !opts.restrict_l1.is_empty() && !opts.restrict_l1.contains(&l1.tile) {
            continue;
        }
        let children = &set.children[1][i];
        let mut best: Option<(f64, usize)> = None;
        for &ci in children {
            chains += 1;
            let child = set.levels[0][ci];
            let sub = Strategy::new(vec![child.tile, l1.tile], l1.backend);
            let c = if opts.profile_all_pairs {
                // Table 7 "Changed": measure the full pair.
                profiler.measure_subchain(dtype, &sub, 1)
            } else {
                hybrid_cost(hw, dtype, &sub, &rank_cfg, profiler)
            };
            if best.map(|(b, _)| c < b).unwrap_or(true) {
                best = Some((c, ci));
            }
        }
        if let Some((_, ci)) = best {
            let child = set.levels[0][ci];
            // Record the chain cost at the configured fidelity.
            let sub = Strategy::new(vec![child.tile, l1.tile], l1.backend);
            let base_cost = hybrid_cost(hw, dtype, &sub, cfg, profiler);
            kernels.push(MicroKernel {
                l0: child.tile,
                l1: l1.tile,
                backend: l1.backend,
                base_cost,
            });
        }
    }

    // 3. Pruning: best survivor per log-shape bucket.
    if opts.prune {
        let mut buckets: HashMap<([u32; 3], usize), MicroKernel> = HashMap::new();
        for k in kernels.drain(..) {
            let key = (log_bucket(k.l1), k.backend);
            match buckets.get(&key) {
                Some(prev) if prev.gflops() >= k.gflops() => {}
                _ => {
                    buckets.insert(key, k);
                }
            }
        }
        kernels = buckets.into_values().collect();
        kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
    }

    let wall_secs = wall0.elapsed().as_secs_f64();
    let tuning = profiler.tuning_secs() - tuning0;
    CompileReport {
        library: MicroKernelLibrary {
            hw_name: hw.name.to_string(),
            dtype,
            analyzer: cfg.clone(),
            kernels,
        },
        candidates_total,
        chains_analyzed: chains,
        profile_queries: profiler.queries() - queries0,
        offline_secs: wall_secs + tuning,
        wall_secs,
    }
}

// ---------------------------------------------------------------------------
// Library (de)serialization — cached next to the artifacts
// ---------------------------------------------------------------------------

impl MicroKernelLibrary {
    pub fn to_json(&self) -> Json {
        let tile =
            |t: [usize; 3]| Json::arr(t.iter().map(|&x| Json::num(x as f64)).collect());
        Json::obj(vec![
            ("hw", Json::str(self.hw_name.clone())),
            ("dtype", Json::str(self.dtype.name())),
            ("analyzer", Json::str(self.analyzer.label())),
            (
                "kernels",
                Json::arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            Json::obj(vec![
                                ("l0", tile(k.l0)),
                                ("l1", tile(k.l1)),
                                ("backend", Json::num(k.backend as f64)),
                                ("base_cost", Json::num(k.base_cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Option<MicroKernelLibrary> {
        let tile = |v: &Json| -> Option<[usize; 3]> {
            let a = v.as_arr()?;
            Some([a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?])
        };
        let analyzer = match v.get("analyzer")?.as_str()? {
            "-" => AnalyzerConfig::analytical_only(),
            "E: L0" => AnalyzerConfig::empirical(0),
            _ => AnalyzerConfig::empirical(1),
        };
        let kernels = v
            .get("kernels")?
            .as_arr()?
            .iter()
            .map(|k| {
                Some(MicroKernel {
                    l0: tile(k.get("l0")?)?,
                    l1: tile(k.get("l1")?)?,
                    backend: k.get("backend")?.as_usize()?,
                    base_cost: k.get("base_cost")?.as_f64()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(MicroKernelLibrary {
            hw_name: v.get("hw")?.as_str()?.to_string(),
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
            analyzer,
            kernels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn compile_tc() -> CompileReport {
        let hw = presets::a100();
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        compile(
            &hw,
            DType::F16,
            &AnalyzerConfig::default_for(&hw),
            &mut prof,
            &CompileOpts::default(),
        )
    }

    #[test]
    fn produces_compact_library() {
        let r = compile_tc();
        assert!(!r.library.kernels.is_empty());
        assert!(
            r.library.kernels.len() <= 512,
            "library too large for fast runtime selection: {}",
            r.library.kernels.len()
        );
        assert!(r.candidates_total > r.library.kernels.len());
    }

    #[test]
    fn kernels_are_valid_chains() {
        let r = compile_tc();
        let hw = presets::a100();
        for k in &r.library.kernels {
            let s = Strategy::new(vec![k.l0, k.l1], k.backend);
            assert!(s.is_nested(), "{:?}", k);
            assert!(k.base_cost > 0.0);
            let ws = crate::hw::HwSpec::gemm_working_set(k.l1, 2);
            assert!(ws <= hw.level(1).capacity_bytes);
        }
    }

    #[test]
    fn offline_seconds_include_tuning() {
        let r = compile_tc();
        assert!(r.profile_queries > 0);
        assert!(r.offline_secs > r.wall_secs);
    }

    #[test]
    fn all_pairs_mode_issues_more_queries() {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut p1 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r1 = compile(&hw, DType::F16, &cfg, &mut p1, &CompileOpts::default());
        let mut p2 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r2 = compile(
            &hw,
            DType::F16,
            &cfg,
            &mut p2,
            &CompileOpts { profile_all_pairs: true, ..CompileOpts::default() },
        );
        assert!(r2.profile_queries > r1.profile_queries);
        assert!(r2.offline_secs > r1.offline_secs);
    }

    #[test]
    fn restriction_matches_real_manifest_blocks() {
        let hw = presets::cpu_pjrt();
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let blocks =
            vec![[64, 256, 512], [128, 512, 512], [128, 768, 768], [16, 128, 256]];
        let r = compile(
            &hw,
            DType::F32,
            &AnalyzerConfig::default_for(&hw),
            &mut prof,
            &CompileOpts {
                restrict_l1: blocks.clone(),
                prune: false,
                ..CompileOpts::default()
            },
        );
        let tiles: Vec<[usize; 3]> = r.library.kernels.iter().map(|k| k.l1).collect();
        for b in blocks {
            assert!(tiles.contains(&b), "block {:?} missing", b);
        }
    }

    #[test]
    fn json_round_trip() {
        let r = compile_tc();
        let j = r.library.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        let lib = MicroKernelLibrary::from_json(&parsed).unwrap();
        assert_eq!(lib.kernels, r.library.kernels);
        assert_eq!(lib.hw_name, "a100");
    }
}
