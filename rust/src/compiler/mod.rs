//! Vortex offline compilation pipeline (paper §5, Fig. 6 left),
//! operator-generic.
//!
//! `compile()` runs the full offline stage for one (hardware, op,
//! dtype) triple:
//!
//! 1. bottom-up candidate generation ([`crate::candgen`], Algorithm 2)
//!    over the op's iteration-space axes;
//! 2. per-candidate strategy analysis with the hybrid analyzer
//!    ([`crate::cost::hybrid`]) — the best child mapping is chosen for
//!    every level-1 candidate and the subchain cost is recorded. The
//!    ranking pass is PARALLELIZED: the few distinct L0 subchains are
//!    profiled once up front (sequentially, so profiler query/tuning
//!    accounting stays exact), then the per-L1 child ranking — pure
//!    arithmetic over those cached measurements — fans out across
//!    threads; the winners' base costs are then profiled sequentially.
//! 3. pruning to a compact [`MicroKernelLibrary`] (near-duplicate tiles
//!    are bucketed by log-shape and only the most efficient survivor of
//!    each bucket is kept), so runtime selection stays microseconds.
//!
//! The library is the *only* artifact the runtime stage needs — no shape
//! samples anywhere (the paper's headline property). With
//! `CompileOpts::cache_dir` set, the library is persisted to disk keyed
//! by (hw, op, dtype, analyzer) and later `compile()` calls load it
//! back instead of re-running candgen + analysis.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::candgen;
use crate::cost::hybrid::{hybrid_cost, AnalyzerConfig};
use crate::cost::{self, Strategy};
use crate::hw::HwSpec;
use crate::ir::{DType, OpKind, Tile, MAX_AXES};
use crate::obs::Span;
use crate::profiler::Profiler;
use crate::util::json::Json;

/// One compiled micro-kernel: an (L0, L1) tile chain with its measured /
/// estimated subchain cost (one L1 block's execution on one unit).
#[derive(Debug, Clone, PartialEq)]
pub struct MicroKernel {
    pub l0: Tile,
    pub l1: Tile,
    pub backend: usize,
    /// Cost of the [l0, l1] subchain, seconds (hybrid analyzer output).
    pub base_cost: f64,
}

impl MicroKernel {
    pub fn flops(&self) -> f64 {
        2.0 * self.l1.product_f64()
    }

    /// Throughput of the block itself, GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.flops() / self.base_cost / 1e9
    }

    /// The runtime strategy chain for a padded problem shape.
    pub fn chain(&self, op: OpKind, padded: Tile) -> Strategy {
        Strategy::for_op(op, vec![self.l0, self.l1, padded], self.backend)
    }

    /// Artifact name convention shared with python/compile/aot.py,
    /// owned by the op.
    pub fn artifact_name(&self, op: OpKind, dtype: DType) -> String {
        op.spec().artifact_name(self.l1, dtype)
    }
}

/// The offline output: a compact set of micro-kernels + bookkeeping.
#[derive(Debug, Clone)]
pub struct MicroKernelLibrary {
    pub hw_name: String,
    pub op: OpKind,
    pub dtype: DType,
    pub analyzer: AnalyzerConfig,
    pub kernels: Vec<MicroKernel>,
    /// Precomputed shape-space dispatch tables shipped with the
    /// library (schema v3, [`crate::dispatch`]): built by
    /// `vortex compile --dispatch` for the single-library selector of
    /// this library, fingerprinted against it. Empty for v1/v2 files
    /// and libraries compiled without `--dispatch`; adoption at load
    /// time goes through [`crate::dispatch::DispatchTable::from_data`]
    /// which refuses fingerprint mismatches (a multi-library serving
    /// selector rebuilds its own table instead).
    pub dispatch: Vec<crate::dispatch::TableData>,
}

/// Offline statistics (paper §7.4 offline-overhead analysis).
#[derive(Debug, Clone)]
pub struct CompileReport {
    pub library: MicroKernelLibrary,
    /// Total candidates generated (Algorithm 2), both levels.
    pub candidates_total: usize,
    /// (L1, child) chains analyzed.
    pub chains_analyzed: usize,
    /// Profiling queries issued.
    pub profile_queries: usize,
    /// Modeled offline wall-clock on the target hardware: candgen +
    /// analysis (measured here) + profiling tuning time (modeled).
    pub offline_secs: f64,
    /// Actual wall-clock spent in this process.
    pub wall_secs: f64,
    /// True when the library was loaded from the on-disk cache (no
    /// candgen / analysis / profiling ran).
    pub from_cache: bool,
    /// Wall-clock of the parallel ranking phase.
    pub analysis_wall_secs: f64,
    /// Sum of per-thread time inside the ranking phase; the ratio
    /// `analysis_cpu_secs / analysis_wall_secs` is the achieved
    /// parallel speedup.
    pub analysis_cpu_secs: f64,
    /// Worker threads used by the ranking phase.
    pub analysis_threads: usize,
    /// Per-phase spans of this compile run (candgen, L0
    /// micro-measurement, parallel ranking, winner profiling,
    /// pruning), offsets from the call's start. Offline time is
    /// genuinely wall-clock, so every span is explicitly
    /// [`crate::obs::SpanClock::Wall`]-marked; profiler-touching
    /// phases carry their query/tuning deltas as span args. Exported
    /// by `vortex compile --trace` via [`crate::obs::compile_trace`].
    pub phases: Vec<crate::obs::Span>,
}

impl CompileReport {
    /// Achieved speedup of the parallel ranking phase (1.0 when it ran
    /// on one thread or was skipped).
    pub fn analysis_speedup(&self) -> f64 {
        if self.analysis_wall_secs > 0.0 {
            (self.analysis_cpu_secs / self.analysis_wall_secs).max(1.0)
        } else {
            1.0
        }
    }
}

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct CompileOpts {
    /// Keep only the best kernel per log-shape bucket.
    pub prune: bool,
    /// Profile every (L1, child) pair instead of only the analytically
    /// best child — Table 7's expensive "Changed" configuration.
    pub profile_all_pairs: bool,
    /// Restrict the library to these L1 tiles (used on the real testbed
    /// to match the AOT artifact set). Empty = no restriction.
    pub restrict_l1: Vec<Tile>,
    /// On-disk library cache directory. When set (and the options are
    /// cacheable: default prune, no all-pairs, no restriction), compile
    /// loads `<hw>_<op>_<dtype>_<analyzer>.json` if present and writes
    /// it after a fresh build.
    pub cache_dir: Option<PathBuf>,
    /// Fingerprint of the AOT artifact set backing the target's blocks
    /// (`runtime::Manifest::fingerprint()` on the real testbed; 0 when
    /// no artifacts are involved). Folded into the cache fingerprint so
    /// regenerated real-testbed blocks invalidate stale caches.
    pub aot_fingerprint: u64,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts {
            prune: true,
            profile_all_pairs: false,
            restrict_l1: Vec::new(),
            cache_dir: None,
            aot_fingerprint: 0,
        }
    }
}

impl CompileOpts {
    /// Only canonical builds go through the cache: restricted or
    /// all-pairs libraries are not representative of the key.
    fn cacheable(&self) -> bool {
        self.prune && !self.profile_all_pairs && self.restrict_l1.is_empty()
    }
}

/// Fingerprint of everything the compiled library depends on besides
/// the visible (hw name, op, dtype, analyzer) key: the full hardware
/// spec contents (an `exp_ablation`-style relaxed clone shares the
/// name but not the space), the profiler's measurement identity
/// (the simulator seed) and the AOT artifact set backing real-testbed
/// blocks (`aot` — see [`CompileOpts::aot_fingerprint`]). Without
/// this, a cache hit could silently return base costs measured under a
/// different seed, spec or artifact build.
fn cache_fingerprint(hw: &HwSpec, profiler: &dyn Profiler, aot: u64) -> u64 {
    let mut parts: Vec<u64> = vec![profiler.fingerprint(), aot];
    for l in &hw.levels {
        parts.push(l.capacity_bytes);
        parts.push(l.load_bw_gbps.to_bits());
        parts.push(l.unit_count as u64);
    }
    for b in &hw.backends {
        parts.push(b.peak_gflops.to_bits());
        parts.extend(b.isa.iter().map(|&x| x as u64));
        parts.push(b.dtype_bytes as u64);
        parts.push(b.launch_factor.to_bits());
    }
    parts.push(hw.min_util.to_bits());
    parts.push(hw.max_l0_per_l1 as u64);
    crate::util::rng::hash_key(&parts)
}

/// Cache file path for one (hw, op, dtype, analyzer, fingerprint) key.
pub fn cache_path(
    dir: &Path,
    hw: &HwSpec,
    op: OpKind,
    dtype: DType,
    cfg: &AnalyzerConfig,
    fingerprint: u64,
) -> PathBuf {
    dir.join(format!(
        "{}_{}_{}_{}_{:016x}.json",
        hw.name,
        op.name(),
        dtype.name(),
        cfg.slug(),
        fingerprint
    ))
}

fn load_cached(
    dir: &Path,
    hw: &HwSpec,
    op: OpKind,
    dtype: DType,
    cfg: &AnalyzerConfig,
    fingerprint: u64,
) -> Option<MicroKernelLibrary> {
    let text =
        std::fs::read_to_string(cache_path(dir, hw, op, dtype, cfg, fingerprint))
            .ok()?;
    let lib = MicroKernelLibrary::from_json(&Json::parse(&text).ok()?)?;
    // The file name is the key, but trust only the content.
    (lib.hw_name == hw.name && lib.op == op && lib.dtype == dtype && lib.analyzer == *cfg)
        .then_some(lib)
}

fn log_bucket(tile: Tile) -> [u32; MAX_AXES] {
    let mut out = [0u32; MAX_AXES];
    for (o, &d) in out.iter_mut().zip(tile.dims()) {
        *o = (d as f64).log2().round() as u32;
    }
    out
}

/// Run the offline stage for one (hardware, op, dtype) triple.
pub fn compile(
    hw: &HwSpec,
    op: OpKind,
    dtype: DType,
    cfg: &AnalyzerConfig,
    profiler: &mut dyn Profiler,
    opts: &CompileOpts,
) -> CompileReport {
    let wall0 = Instant::now();
    let fp = cache_fingerprint(hw, profiler, opts.aot_fingerprint);
    if let Some(dir) = opts.cache_dir.as_deref() {
        if opts.cacheable() {
            if let Some(library) = load_cached(dir, hw, op, dtype, cfg, fp) {
                let wall_secs = wall0.elapsed().as_secs_f64();
                return CompileReport {
                    library,
                    candidates_total: 0,
                    chains_analyzed: 0,
                    profile_queries: 0,
                    offline_secs: 0.0,
                    wall_secs,
                    from_cache: true,
                    analysis_wall_secs: 0.0,
                    analysis_cpu_secs: 0.0,
                    analysis_threads: 0,
                    phases: vec![Span::complete(
                        "cache_load",
                        "compile",
                        0,
                        0,
                        0.0,
                        wall_secs,
                    )
                    .wall()],
                };
            }
        }
    }
    let queries0 = profiler.queries();
    let tuning0 = profiler.tuning_secs();
    // Per-phase spans, offsets from `wall0`. Offline time is real
    // wall-clock by nature, so every span is explicitly Wall-marked —
    // the trace schema (and `analysis::audit_trace`) keeps measured
    // time distinguishable from the serving layer's event-clock spans.
    let mut phases: Vec<Span> = Vec::new();
    let phase = |name: &str, cat: &str, start: f64, end: f64, args: Vec<(&str, Json)>| {
        let mut s = Span::complete(name, cat, 0, 0, start, end - start).wall();
        for (k, v) in args {
            s = s.arg(k, v);
        }
        s
    };

    // 1. Algorithm 2 over the op's axes.
    let mut t_phase = wall0.elapsed().as_secs_f64();
    let set = candgen::generate(hw, op, dtype);
    let candidates_total = set.total();
    let t_end = wall0.elapsed().as_secs_f64();
    phases.push(phase(
        "candgen",
        "compile",
        t_phase,
        t_end,
        vec![("candidates", Json::num(candidates_total as f64))],
    ));
    t_phase = t_end;

    // 2. Strategy analysis: best child per L1 candidate. Children are
    // RANKED with at most L0-empirical splicing (distinct L0 tiles are
    // few, so this is cheap); only the WINNING pair is then profiled at
    // the configured fidelity — this is what keeps the paper's offline
    // query counts at ~(#L0 + #L1) instead of #chains. The
    // `profile_all_pairs` flag (Table 7 "Changed") measures every pair.
    let rank_empirical = cfg.empirical_up_to.is_some();
    let l1_list: Vec<usize> = (0..set.levels[1].len())
        .filter(|&i| {
            opts.restrict_l1.is_empty()
                || opts.restrict_l1.contains(&set.levels[1][i].tile)
        })
        .collect();

    // Per-L1 winner: (ranking cost, child index).
    let mut winners: Vec<Option<(f64, usize)>> = vec![None; l1_list.len()];
    let mut chains = 0usize;
    let mut analysis_wall_secs = 0.0;
    let mut analysis_cpu_secs = 0.0;
    let mut analysis_threads = 1usize;

    if opts.profile_all_pairs {
        // Table 7 "Changed": measure the full pair, sequentially, so the
        // profiler's query/tuning accounting stays exact.
        let prof0 = profiler.snapshot();
        for (slot, &i) in winners.iter_mut().zip(&l1_list) {
            let l1 = set.levels[1][i];
            for &ci in &set.children[1][i] {
                chains += 1;
                let child = set.levels[0][ci];
                let sub =
                    Strategy::for_op(op, vec![child.tile, l1.tile], l1.backend);
                let c = profiler.measure_subchain(dtype, &sub, 1);
                if slot.map(|(b, _)| c < b).unwrap_or(true) {
                    *slot = Some((c, ci));
                }
            }
        }
        let t_end = wall0.elapsed().as_secs_f64();
        let d = profiler.snapshot().since(prof0);
        phases.push(phase(
            "profile_pairs",
            "profiler",
            t_phase,
            t_end,
            vec![
                ("queries", Json::num(d.queries as f64)),
                ("tuning_secs", Json::num(d.tuning_secs)),
            ],
        ));
        t_phase = t_end;
    } else {
        // Phase A (sequential, profiler): measure each distinct L0
        // subchain once — exactly the measurement set the ranking needs.
        let mut l0_cost: HashMap<(Tile, usize), f64> = HashMap::new();
        if rank_empirical {
            let prof0 = profiler.snapshot();
            for &i in &l1_list {
                for &ci in &set.children[1][i] {
                    let child = set.levels[0][ci];
                    l0_cost.entry((child.tile, child.backend)).or_insert_with(|| {
                        let sub =
                            Strategy::for_op(op, vec![child.tile], child.backend);
                        profiler.measure_subchain(dtype, &sub, 0)
                    });
                }
            }
            let t_end = wall0.elapsed().as_secs_f64();
            let d = profiler.snapshot().since(prof0);
            phases.push(phase(
                "measure_l0",
                "profiler",
                t_phase,
                t_end,
                vec![
                    ("queries", Json::num(d.queries as f64)),
                    ("tuning_secs", Json::num(d.tuning_secs)),
                    ("distinct_l0", Json::num(l0_cost.len() as f64)),
                ],
            ));
            t_phase = t_end;
        }
        // Phase B (parallel, pure arithmetic): rank every child of every
        // L1 candidate with Eq. 2–4 over the cached L0 measurements.
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(1, 16)
            .min(l1_list.len().max(1));
        let chunk = l1_list.len().div_ceil(threads).max(1);
        let t_wall = Instant::now();
        let (cpu_secs, pair_counts): (Vec<f64>, Vec<usize>) =
            std::thread::scope(|s| {
                let l0_cost = &l0_cost;
                let set = &set;
                let handles: Vec<_> = winners
                    .chunks_mut(chunk)
                    .zip(l1_list.chunks(chunk))
                    .map(|(slots, idxs)| {
                        s.spawn(move || {
                            let t0 = Instant::now();
                            let mut pairs = 0usize;
                            for (slot, &i) in slots.iter_mut().zip(idxs) {
                                let l1 = set.levels[1][i];
                                for &ci in &set.children[1][i] {
                                    pairs += 1;
                                    let child = set.levels[0][ci];
                                    let sub = Strategy::for_op(
                                        op,
                                        vec![child.tile, l1.tile],
                                        l1.backend,
                                    );
                                    let c = if rank_empirical {
                                        let base =
                                            l0_cost[&(child.tile, child.backend)];
                                        cost::cost_from(hw, dtype, &sub, 1, base)
                                            .total_secs
                                    } else {
                                        cost::cost(hw, dtype, &sub, None).total_secs
                                    };
                                    if slot.map(|(b, _)| c < b).unwrap_or(true) {
                                        *slot = Some((c, ci));
                                    }
                                }
                            }
                            (t0.elapsed().as_secs_f64(), pairs)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).unzip()
            });
        analysis_wall_secs = t_wall.elapsed().as_secs_f64();
        analysis_cpu_secs = cpu_secs.iter().sum();
        // Workers actually spawned (chunk rounding can yield fewer
        // than the planned thread count).
        analysis_threads = cpu_secs.len().max(1);
        chains = pair_counts.iter().sum();
        let t_end = wall0.elapsed().as_secs_f64();
        phases.push(phase(
            "rank",
            "compile",
            t_phase,
            t_end,
            vec![
                ("chains", Json::num(chains as f64)),
                ("threads", Json::num(analysis_threads as f64)),
                ("cpu_secs", Json::num(analysis_cpu_secs)),
            ],
        ));
        t_phase = t_end;
    }

    // Phase C (sequential, profiler): record each winner's chain cost at
    // the configured fidelity.
    let prof0 = profiler.snapshot();
    let mut kernels: Vec<MicroKernel> = Vec::new();
    for (slot, &i) in winners.iter().zip(&l1_list) {
        if let Some((_, ci)) = *slot {
            let l1 = set.levels[1][i];
            let child = set.levels[0][ci];
            let sub = Strategy::for_op(op, vec![child.tile, l1.tile], l1.backend);
            let base_cost = hybrid_cost(hw, dtype, &sub, cfg, profiler);
            kernels.push(MicroKernel {
                l0: child.tile,
                l1: l1.tile,
                backend: l1.backend,
                base_cost,
            });
        }
    }
    {
        let t_end = wall0.elapsed().as_secs_f64();
        let d = profiler.snapshot().since(prof0);
        phases.push(phase(
            "profile_winners",
            "profiler",
            t_phase,
            t_end,
            vec![
                ("queries", Json::num(d.queries as f64)),
                ("tuning_secs", Json::num(d.tuning_secs)),
                ("winners", Json::num(kernels.len() as f64)),
            ],
        ));
        t_phase = t_end;
    }

    // 3. Pruning: best survivor per log-shape bucket.
    if opts.prune {
        let mut buckets: HashMap<([u32; MAX_AXES], usize), MicroKernel> =
            HashMap::new();
        for k in kernels.drain(..) {
            let key = (log_bucket(k.l1), k.backend);
            match buckets.get(&key) {
                Some(prev) if prev.gflops() >= k.gflops() => {}
                _ => {
                    buckets.insert(key, k);
                }
            }
        }
        kernels = buckets.into_values().collect();
        kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
        let t_end = wall0.elapsed().as_secs_f64();
        phases.push(phase(
            "prune",
            "compile",
            t_phase,
            t_end,
            vec![("kept", Json::num(kernels.len() as f64))],
        ));
    }

    let wall_secs = wall0.elapsed().as_secs_f64();
    let tuning = profiler.tuning_secs() - tuning0;
    let report = CompileReport {
        library: MicroKernelLibrary {
            hw_name: hw.name.to_string(),
            op,
            dtype,
            analyzer: cfg.clone(),
            kernels,
            dispatch: Vec::new(),
        },
        candidates_total,
        chains_analyzed: chains,
        profile_queries: profiler.queries() - queries0,
        offline_secs: wall_secs + tuning,
        wall_secs,
        from_cache: false,
        analysis_wall_secs,
        analysis_cpu_secs,
        analysis_threads,
        phases,
    };
    if let Some(dir) = opts.cache_dir.as_deref() {
        if opts.cacheable() {
            let _ = std::fs::create_dir_all(dir);
            let _ = std::fs::write(
                cache_path(dir, hw, op, dtype, cfg, fp),
                report.library.to_json().dump(),
            );
        }
    }
    report
}

impl MicroKernelLibrary {
    /// Lift this library onto a batch-extended op: the target op's axes
    /// must be this op's axes behind one leading batch axis (e.g. Gemm
    /// → BatchedGemm / GroupedConv2d). Every kernel's tiles gain a
    /// leading batch extent of 1 — exactly how the real runtime serves
    /// batched and grouped ops today, as a loop of contraction blocks —
    /// so each lifted `base_cost` stays the per-batch-element block
    /// cost. Returns `None` when the axis layouts are incompatible.
    ///
    /// Invariants of the lifted library: kernel count, backends and
    /// base costs are unchanged; every lifted tile has rank
    /// `self.op.rank() + 1` with a leading extent of exactly 1 (so the
    /// lifted chains still nest). Lifting is not idempotent — lifting
    /// an already-batched library returns `None` rather than stacking
    /// batch axes. A lifted BatchedGemm library also serves
    /// FusedAttention spaces through the selector's measurement-alias
    /// fixpoint (the real runtime's attention path).
    pub fn lift_to_batched(&self, op: OpKind) -> Option<MicroKernelLibrary> {
        use crate::ir::AxisRole;
        let src = self.op.spec().axes();
        let dst = op.spec().axes();
        let compatible = dst.len() == src.len() + 1
            && dst[0].role == AxisRole::Batch
            && dst[1..].iter().zip(src).all(|(d, s)| d.role == s.role);
        if !compatible {
            return None;
        }
        let lift = |t: Tile| {
            let mut dims = vec![1usize];
            dims.extend_from_slice(t.dims());
            Tile::new(&dims)
        };
        Some(MicroKernelLibrary {
            hw_name: self.hw_name.clone(),
            op,
            dtype: self.dtype,
            analyzer: self.analyzer.clone(),
            kernels: self
                .kernels
                .iter()
                .map(|k| MicroKernel {
                    l0: lift(k.l0),
                    l1: lift(k.l1),
                    backend: k.backend,
                    base_cost: k.base_cost,
                })
                .collect(),
            // Any embedded dispatch tables were fingerprinted against
            // the UNLIFTED library; they do not carry over.
            dispatch: Vec::new(),
        })
    }
}

// ---------------------------------------------------------------------------
// Library (de)serialization — cached next to the artifacts
// ---------------------------------------------------------------------------

/// Current library schema version. v1 (implicit) had no "version"/"op"
/// fields and was GEMM-only; v2 adds both; v3 adds the optional
/// `"dispatch"` field — precomputed shape-space dispatch tables
/// ([`crate::dispatch::TableData`]) fingerprinted against the
/// single-library selector they were built for. v1 and v2 files still
/// load (with no tables); a v3 file whose `"dispatch"` payload is
/// malformed is rejected outright, like every other strict-loader
/// failure.
///
/// Valid `"op"` strings are exactly the [`OpKind::parse`] names:
/// `"gemm"`, `"batched_gemm"`, `"conv2d"`, `"grouped_conv2d"` and
/// `"attention"` — one per registered strategy space. `"softmax"` is
/// deliberately NOT a valid op: the row-softmax is the fused epilogue
/// of the attention chain, priced by a profiler micro-measurement
/// folded into the attention kernels' `base_cost`, never a standalone
/// library. Fused chains need no library of their own to be servable:
/// the selector serves an `"attention"` space through `"batched_gemm"`
/// libraries via the measurement-alias fixpoint (one alias block per
/// constituent kernel), so a deployment that only ever compiled
/// batched-GEMM libraries still executes attention chains.
pub const LIBRARY_SCHEMA_VERSION: usize = 3;

impl MicroKernelLibrary {
    pub fn to_json(&self) -> Json {
        let tile = |t: Tile| {
            Json::arr(t.iter().map(|&x| Json::num(x as f64)).collect())
        };
        let mut fields = vec![
            ("version", Json::num(LIBRARY_SCHEMA_VERSION as f64)),
            ("hw", Json::str(self.hw_name.clone())),
            ("op", Json::str(self.op.name())),
            ("dtype", Json::str(self.dtype.name())),
            ("analyzer", Json::str(self.analyzer.label())),
            (
                "kernels",
                Json::arr(
                    self.kernels
                        .iter()
                        .map(|k| {
                            Json::obj(vec![
                                ("l0", tile(k.l0)),
                                ("l1", tile(k.l1)),
                                ("backend", Json::num(k.backend as f64)),
                                ("base_cost", Json::num(k.base_cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !self.dispatch.is_empty() {
            fields.push((
                "dispatch",
                Json::arr(self.dispatch.iter().map(|d| d.to_json()).collect()),
            ));
        }
        Json::obj(fields)
    }

    /// Strict loader: unknown schema versions, unknown ops, unknown
    /// analyzer labels and rank-mismatched tiles all return `None`
    /// (never a silently-misclassified library). A missing "version" /
    /// "op" means a legacy v1 GEMM-only file, which still loads.
    pub fn from_json(v: &Json) -> Option<MicroKernelLibrary> {
        let version = match v.get("version") {
            None => 1,
            Some(x) => x.as_usize()?,
        };
        if !(1..=LIBRARY_SCHEMA_VERSION).contains(&version) {
            return None;
        }
        let op = match v.get("op") {
            None => OpKind::Gemm,
            Some(o) => OpKind::parse(o.as_str()?)?,
        };
        let rank = op.spec().rank();
        let tile = |v: &Json| -> Option<Tile> {
            let a = v.as_arr()?;
            if a.len() != rank {
                return None;
            }
            let dims: Vec<usize> =
                a.iter().map(|x| x.as_usize()).collect::<Option<Vec<_>>>()?;
            Some(Tile::new(&dims))
        };
        let analyzer = AnalyzerConfig::parse_label(v.get("analyzer")?.as_str()?)?;
        let kernels = v
            .get("kernels")?
            .as_arr()?
            .iter()
            .map(|k| {
                Some(MicroKernel {
                    l0: tile(k.get("l0")?)?,
                    l1: tile(k.get("l1")?)?,
                    backend: k.get("backend")?.as_usize()?,
                    base_cost: k.get("base_cost")?.as_f64()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        // v3: optional embedded dispatch tables. Absent (v1/v2 or no
        // --dispatch compile) means none; present-but-malformed is a
        // load error, not a silent drop.
        let dispatch = match v.get("dispatch") {
            None => Vec::new(),
            Some(d) => d
                .as_arr()?
                .iter()
                .map(crate::dispatch::TableData::from_json)
                .collect::<Option<Vec<_>>>()?,
        };
        Some(MicroKernelLibrary {
            hw_name: v.get("hw")?.as_str()?.to_string(),
            op,
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
            analyzer,
            kernels,
            dispatch,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn compile_op(op: OpKind) -> CompileReport {
        let hw = presets::a100();
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        compile(
            &hw,
            op,
            DType::F16,
            &AnalyzerConfig::default_for(&hw),
            &mut prof,
            &CompileOpts::default(),
        )
    }

    fn compile_tc() -> CompileReport {
        compile_op(OpKind::Gemm)
    }

    #[test]
    fn produces_compact_library() {
        let r = compile_tc();
        assert!(!r.library.kernels.is_empty());
        assert!(
            r.library.kernels.len() <= 512,
            "library too large for fast runtime selection: {}",
            r.library.kernels.len()
        );
        assert!(r.candidates_total > r.library.kernels.len());
        assert!(r.analysis_threads >= 1);
        assert!(r.analysis_speedup() >= 1.0);
        assert!(!r.from_cache);
    }

    #[test]
    fn kernels_are_valid_chains() {
        let r = compile_tc();
        let hw = presets::a100();
        for k in &r.library.kernels {
            let s = Strategy::for_op(OpKind::Gemm, vec![k.l0, k.l1], k.backend);
            assert!(s.is_nested(), "{:?}", k);
            assert!(k.base_cost > 0.0);
            let ws = crate::hw::HwSpec::gemm_working_set(k.l1.to3(), 2);
            assert!(ws <= hw.level(1).capacity_bytes);
        }
    }

    #[test]
    fn offline_seconds_include_tuning() {
        let r = compile_tc();
        assert!(r.profile_queries > 0);
        assert!(r.offline_secs > r.wall_secs);
    }

    #[test]
    fn all_pairs_mode_issues_more_queries() {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut p1 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r1 = compile(
            &hw,
            OpKind::Gemm,
            DType::F16,
            &cfg,
            &mut p1,
            &CompileOpts::default(),
        );
        let mut p2 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r2 = compile(
            &hw,
            OpKind::Gemm,
            DType::F16,
            &cfg,
            &mut p2,
            &CompileOpts { profile_all_pairs: true, ..CompileOpts::default() },
        );
        assert!(r2.profile_queries > r1.profile_queries);
        assert!(r2.offline_secs > r1.offline_secs);
    }

    #[test]
    fn restriction_matches_real_manifest_blocks() {
        let hw = presets::cpu_pjrt();
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let blocks: Vec<Tile> =
            [[64, 256, 512], [128, 512, 512], [128, 768, 768], [16, 128, 256]]
                .into_iter()
                .map(Tile::from3)
                .collect();
        let r = compile(
            &hw,
            OpKind::Gemm,
            DType::F32,
            &AnalyzerConfig::default_for(&hw),
            &mut prof,
            &CompileOpts {
                restrict_l1: blocks.clone(),
                prune: false,
                ..CompileOpts::default()
            },
        );
        let tiles: Vec<Tile> = r.library.kernels.iter().map(|k| k.l1).collect();
        for b in blocks {
            assert!(tiles.contains(&b), "block {:?} missing", b);
        }
    }

    #[test]
    fn json_round_trip() {
        let r = compile_tc();
        let j = r.library.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        let lib = MicroKernelLibrary::from_json(&parsed).unwrap();
        assert_eq!(lib.kernels, r.library.kernels);
        assert_eq!(lib.hw_name, "a100");
        assert_eq!(lib.op, OpKind::Gemm);
    }

    #[test]
    fn batched_gemm_json_round_trips_rank_four_tiles() {
        let r = compile_op(OpKind::BatchedGemm);
        assert!(!r.library.kernels.is_empty());
        let parsed = Json::parse(&r.library.to_json().dump()).unwrap();
        let lib = MicroKernelLibrary::from_json(&parsed).unwrap();
        assert_eq!(lib.op, OpKind::BatchedGemm);
        assert_eq!(lib.kernels, r.library.kernels);
        assert!(lib.kernels.iter().all(|k| k.l1.rank() == 4));
    }

    #[test]
    fn schema_v3_dispatch_round_trips_and_legacy_v2_loads() {
        use crate::coordinator::{HwMode, Selector};
        use crate::dispatch::{DispatchConfig, DispatchTable};
        use crate::ir::IterSpace;
        let hw = presets::a100();
        let r = compile_tc();
        let mut lib = r.library.clone();
        let selector = Selector::new(hw.clone(), vec![lib.clone()]);
        let table = DispatchTable::for_selector(&selector, &DispatchConfig::default());
        lib.dispatch = table.to_data(&selector);
        assert!(!lib.dispatch.is_empty());
        let text = lib.to_json().dump();
        assert!(text.contains("\"version\":3"));
        assert!(text.contains("\"dispatch\""));
        let loaded = MicroKernelLibrary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(loaded.kernels, lib.kernels);
        assert_eq!(loaded.dispatch, lib.dispatch);
        // Adoption: a selector over the loaded library accepts the
        // shipped tables (same fingerprint) and answers identically to
        // fresh selection — the zero-warm-up deployment path.
        let s2 = Selector::new(hw, vec![loaded.clone()]);
        let adopted =
            DispatchTable::from_data(&s2, &loaded.dispatch).expect("fingerprint must match");
        let space = IterSpace::gemm(33, 100, 77, DType::F16);
        let a = adopted.select(&s2, space, HwMode::Adaptive).expect("in-horizon");
        let fresh = s2.select(space, HwMode::Adaptive).unwrap();
        assert!(fresh.same_plan(&a));
        // A v2 file (no dispatch field) still loads...
        let v2 = r.library.to_json().dump().replace("\"version\":3", "\"version\":2");
        let lib_v2 = MicroKernelLibrary::from_json(&Json::parse(&v2).unwrap()).unwrap();
        assert!(lib_v2.dispatch.is_empty());
        assert_eq!(lib_v2.kernels, r.library.kernels);
        // ...while a malformed dispatch payload is a LOAD error (strict
        // loader), not a silent drop.
        let bad = text.replace("\"fingerprint\":\"", "\"fingerprint\":\"zz");
        assert!(MicroKernelLibrary::from_json(&Json::parse(&bad).unwrap()).is_none());
    }

    #[test]
    fn legacy_v1_gemm_json_still_loads() {
        // A pre-versioning library file: no "version", no "op".
        let text = r#"{"analyzer":"E: L0, L1","dtype":"f16","hw":"a100",
            "kernels":[{"backend":1,"base_cost":1e-6,
                        "l0":[16,8,16],"l1":[64,64,32]}]}"#;
        let lib = MicroKernelLibrary::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(lib.op, OpKind::Gemm);
        assert_eq!(lib.kernels.len(), 1);
        assert_eq!(lib.kernels[0].l1, Tile::from3([64, 64, 32]));
        assert_eq!(lib.analyzer, AnalyzerConfig::empirical(1));
    }

    #[test]
    fn strict_loader_rejects_unknown_input() {
        let ok = compile_tc().library.to_json().dump();
        // unknown analyzer label
        let bad1 = ok.replace("E: L0, L1", "E: mystery");
        assert!(
            MicroKernelLibrary::from_json(&Json::parse(&bad1).unwrap()).is_none()
        );
        // unknown schema version
        let bad2 = ok.replace("\"version\":3", "\"version\":99");
        assert!(
            MicroKernelLibrary::from_json(&Json::parse(&bad2).unwrap()).is_none()
        );
        // "softmax" is not an op string BY DESIGN (see
        // LIBRARY_SCHEMA_VERSION): the row-softmax is the attention
        // chain's measured epilogue, never a library key — attention
        // spaces serve through "batched_gemm" libraries instead.
        let bad3 = ok.replace("\"op\":\"gemm\"", "\"op\":\"softmax\"");
        assert!(
            MicroKernelLibrary::from_json(&Json::parse(&bad3).unwrap()).is_none()
        );
        // ...while every registered op string, "attention" included,
        // loads as a v2 library.
        for op in OpKind::ALL {
            let renamed = ok.replace("\"op\":\"gemm\"", &format!("\"op\":\"{}\"", op.name()));
            let lib = MicroKernelLibrary::from_json(&Json::parse(&renamed).unwrap());
            if op.spec().rank() == 3 {
                assert!(lib.is_some(), "{} library failed to load", op);
            } else {
                // rank-mismatched tiles are rejected, not mis-ranked
                assert!(lib.is_none(), "{} accepted rank-3 tiles", op);
            }
        }
    }

    #[test]
    fn disk_cache_round_trips_and_skips_recompilation() {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let dir = std::env::temp_dir().join("vortex_lib_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = CompileOpts { cache_dir: Some(dir.clone()), ..CompileOpts::default() };
        let mut p1 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r1 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p1, &opts);
        assert!(!r1.from_cache);
        let fp = cache_fingerprint(&hw, &p1, 0);
        assert!(cache_path(&dir, &hw, OpKind::Gemm, DType::F16, &cfg, fp).exists());
        let mut p2 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r2 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p2, &opts);
        assert!(r2.from_cache);
        assert_eq!(p2.queries(), 0, "cached load must not profile");
        assert_eq!(r2.library.kernels, r1.library.kernels);
        // A different key (op) misses the cache.
        let mut p3 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r3 = compile(&hw, OpKind::Conv2d, DType::F16, &cfg, &mut p3, &opts);
        assert!(!r3.from_cache);
        // A different measurement source (simulator seed) must miss too:
        // its base costs would not match the cached library's.
        let mut p4 = SimProfiler::new(Simulator::new(hw.clone(), 6));
        let r4 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p4, &opts);
        assert!(!r4.from_cache, "seed change aliased in the cache");
        // ...and so must a mutated hardware spec sharing the name.
        let mut relaxed = hw.clone();
        relaxed.min_util = 0.0;
        let mut p5 = SimProfiler::new(Simulator::new(relaxed.clone(), 5));
        let r5 = compile(&relaxed, OpKind::Gemm, DType::F16, &cfg, &mut p5, &opts);
        assert!(!r5.from_cache, "hw-spec change aliased in the cache");
        // ...and so must a changed softmax micro-measurement definition
        // (ROADMAP offline-stage item): the measurement inputs are part
        // of the profiler fingerprint, so a library built under the old
        // definition never serves a compile under the new one.
        let mut p6 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        p6.softmax_ops_per_elem = 2.0 * crate::profiler::SOFTMAX_OPS_PER_ELEM;
        let r6 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p6, &opts);
        assert!(!r6.from_cache, "softmax-measurement change aliased in the cache");
        // ...and so must a changed AOT artifact set (ROADMAP real-
        // testbed item): a library built against one Pallas block build
        // never serves a compile against a regenerated one — while the
        // SAME artifact fingerprint still hits its own cache entry.
        let aot_opts = CompileOpts { aot_fingerprint: 0xA07, ..opts.clone() };
        let mut p7 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r7 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p7, &aot_opts);
        assert!(!r7.from_cache, "AOT-artifact change aliased in the cache");
        let mut p8 = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r8 = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut p8, &aot_opts);
        assert!(r8.from_cache, "unchanged AOT fingerprint must hit");
        assert_eq!(r8.library.kernels, r7.library.kernels);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_ranking_matches_sequential_reference() {
        // The hoisted Phase A/B fan-out must pick exactly the winners a
        // sequential per-pair `hybrid_cost` ranking (the pre-refactor
        // code path) picks, for every L1 candidate.
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let lib = compile(
            &hw,
            OpKind::Gemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts { prune: false, ..CompileOpts::default() },
        )
        .library;

        // Sequential reference: rank every child with L0-empirical
        // splicing, exactly as the old loop did.
        let set = candgen::generate(&hw, OpKind::Gemm, DType::F16);
        let rank_cfg = AnalyzerConfig::empirical(0);
        let mut ref_prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let mut expected: Vec<(Tile, Tile)> = Vec::new();
        for (i, l1) in set.levels[1].iter().enumerate() {
            let mut best: Option<(f64, usize)> = None;
            for &ci in &set.children[1][i] {
                let child = set.levels[0][ci];
                let sub = Strategy::for_op(
                    OpKind::Gemm,
                    vec![child.tile, l1.tile],
                    l1.backend,
                );
                let c = hybrid_cost(&hw, DType::F16, &sub, &rank_cfg, &mut ref_prof);
                if best.map(|(b, _)| c < b).unwrap_or(true) {
                    best = Some((c, ci));
                }
            }
            let (_, ci) = best.unwrap();
            expected.push((set.levels[0][ci].tile, l1.tile));
        }
        let got: Vec<(Tile, Tile)> =
            lib.kernels.iter().map(|k| (k.l0, k.l1)).collect();
        assert_eq!(got, expected);
    }

    #[test]
    fn conv_compile_shares_gemm_measurements() {
        // Conv2d's formulas delegate to Gemm, so compiling its library
        // with a profiler already warmed by the GEMM compile must issue
        // ZERO new measurements (measurement-op cache aliasing).
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let g = compile(
            &hw,
            OpKind::Gemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        );
        assert!(g.profile_queries > 0);
        let c = compile(
            &hw,
            OpKind::Conv2d,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        );
        assert_eq!(c.profile_queries, 0, "conv re-measured gemm subchains");
        // Same strategy space + same measurements => same tile chains.
        let tiles =
            |l: &MicroKernelLibrary| l.kernels.iter().map(|k| (k.l0, k.l1)).collect::<Vec<_>>();
        assert_eq!(tiles(&g.library), tiles(&c.library));
    }

    #[test]
    fn grouped_conv_compile_shares_batched_gemm_measurements() {
        // GroupedConv2d's formulas delegate to BatchedGemm, so compiling
        // its library with a profiler already warmed by the batched-GEMM
        // compile must issue ZERO new measurements.
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let b = compile(
            &hw,
            OpKind::BatchedGemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        );
        assert!(b.profile_queries > 0);
        let g = compile(
            &hw,
            OpKind::GroupedConv2d,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        );
        assert_eq!(g.profile_queries, 0, "grouped conv re-measured bgemm subchains");
        let tiles = |l: &MicroKernelLibrary| {
            l.kernels.iter().map(|k| (k.l0, k.l1)).collect::<Vec<_>>()
        };
        assert_eq!(tiles(&b.library), tiles(&g.library));
        assert!(g.library.kernels.iter().all(|k| k.l1.rank() == 4));
    }

    #[test]
    fn attention_compile_shares_batched_gemm_measurements_plus_softmax() {
        // The fused chain's contraction blocks alias BatchedGemm: with
        // a profiler warmed by the batched-GEMM compile, the attention
        // compile re-measures NO shared contraction subchain — its new
        // queries are the softmax micro-measurements plus winner pairs
        // outside the batched library's measured set. A cold attention
        // compile measures every L0 subchain itself, so warm must be
        // strictly cheaper; and the library is identical either way.
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut cold = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let r_cold = compile(
            &hw,
            OpKind::FusedAttention,
            DType::F16,
            &cfg,
            &mut cold,
            &CompileOpts::default(),
        );
        assert!(!r_cold.library.kernels.is_empty());
        assert!(r_cold.profile_queries > 0);

        let mut warm = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let b = compile(
            &hw,
            OpKind::BatchedGemm,
            DType::F16,
            &cfg,
            &mut warm,
            &CompileOpts::default(),
        );
        assert!(b.profile_queries > 0);
        let r_warm = compile(
            &hw,
            OpKind::FusedAttention,
            DType::F16,
            &cfg,
            &mut warm,
            &CompileOpts::default(),
        );
        assert!(
            r_warm.profile_queries < r_cold.profile_queries,
            "warm {} !< cold {}: no measurement sharing happened",
            r_warm.profile_queries,
            r_cold.profile_queries
        );
        assert!(r_warm.profile_queries > 0, "softmax measurements are real");
        let tiles = |l: &MicroKernelLibrary| {
            l.kernels.iter().map(|k| (k.l0, k.l1)).collect::<Vec<_>>()
        };
        assert_eq!(tiles(&r_cold.library), tiles(&r_warm.library));
        assert!(r_cold.library.kernels.iter().all(|k| k.l1.rank() == 4));
        // Determinism at fixpoint: a THIRD compile on the warm profiler
        // issues zero queries (every block and softmax tile cached).
        let r_again = compile(
            &hw,
            OpKind::FusedAttention,
            DType::F16,
            &cfg,
            &mut warm,
            &CompileOpts::default(),
        );
        assert_eq!(r_again.profile_queries, 0);
        // Per-kernel cost exceeds the aliased batched block cost: both
        // contractions plus the softmax epilogue are priced in.
        for k in &r_cold.library.kernels {
            assert!(k.base_cost > 0.0);
        }
    }

    #[test]
    fn gemm_library_lifts_onto_batch_extended_ops() {
        let r = compile_tc();
        for op in [OpKind::BatchedGemm, OpKind::GroupedConv2d] {
            let lifted = r.library.lift_to_batched(op).unwrap();
            assert_eq!(lifted.op, op);
            assert_eq!(lifted.kernels.len(), r.library.kernels.len());
            for (l, k) in lifted.kernels.iter().zip(&r.library.kernels) {
                assert_eq!(l.l1.rank(), 4);
                assert_eq!(l.l1[0], 1);
                assert_eq!([l.l1[1], l.l1[2], l.l1[3]], k.l1.to3());
                assert_eq!(l.base_cost, k.base_cost);
            }
        }
        // Incompatible layouts refuse to lift.
        assert!(r.library.lift_to_batched(OpKind::Gemm).is_none());
        let b = r.library.lift_to_batched(OpKind::BatchedGemm).unwrap();
        assert!(b.lift_to_batched(OpKind::BatchedGemm).is_none());
    }
}
