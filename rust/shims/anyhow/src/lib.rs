//! Minimal offline stand-in for the `anyhow` crate: just the API
//! subset the vortex runtime uses (`anyhow!`, `bail!`, `Context`,
//! `Result`). No backtraces, no error chains — a single message.

use std::fmt;

pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Attach context to a failure, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", c, e)))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}
