//! Offline stub of the `xla` crate (the PJRT CPU client used by the
//! real testbed). It type-checks the exact API surface
//! `vortex::runtime` consumes and returns a descriptive error at call
//! time, so the crate builds and the simulated testbeds run everywhere;
//! swap in the real `xla` crate (xla_extension) to execute the AOT
//! artifacts. `RealEngine` construction fails fast through
//! `PjRtClient::cpu()`, and the real-path tests skip when artifacts are
//! absent, so the stub never silently fakes an execution.

use std::fmt;
use std::path::Path;

pub struct Error {
    msg: String,
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<Error> for anyhow::Error {
    fn from(e: Error) -> anyhow::Error {
        anyhow::Error::msg(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error {
        msg: "PJRT backend not available in this offline build; \
              link the real `xla` crate to run AOT artifacts"
            .to_string(),
    })
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F16,
    Bf16,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F16,
    Bf16,
}

pub struct Shape;

impl Shape {
    pub fn is_tuple(&self) -> bool {
        false
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable()
    }
    pub fn shape(&self) -> Result<Shape> {
        unavailable()
    }
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }
    pub fn ty(&self) -> Result<ElementType> {
        unavailable()
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
    /// Upload an already-shaped (and dtype-converted) literal to a
    /// device buffer — the profiling path pre-uploads inputs once with
    /// this so timed reps measure pure `execute_b` launches.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}
