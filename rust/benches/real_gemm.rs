//! Bench: REAL end-to-end dynamic GEMM through the PJRT kernel
//! constructor (artifacts required; prints SKIP otherwise).
//! Run with `make artifacts && cargo bench --bench real_gemm`.

use std::path::PathBuf;

use vortex::coordinator::{HwMode, Selector};
use vortex::hw::presets;
use vortex::ir::{Contraction, DType};
use vortex::runtime::{build_real_library, RealEngine};
use vortex::util::bench::{black_box, Bench};
use vortex::util::rng::Rng;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP real_gemm: run `make artifacts` first");
        return;
    }
    let engine = RealEngine::load(&dir).expect("engine");
    let hw = presets::cpu_pjrt();
    let lib = build_real_library(&engine, &hw, DType::F32, 2).expect("library");
    let selector = Selector::new(hw, vec![lib]);

    let b = Bench::quick();
    let mut rng = Rng::new(1);
    for (m, n, k) in [(77usize, 768usize, 768usize), (128, 768, 768), (200, 512, 1024), (16, 256, 256)] {
        let a = rng.normal_f32_vec(m * k);
        let bmat = rng.normal_f32_vec(k * n);
        let c = Contraction { m, n, k, dtype: DType::F32 };
        let sel = selector.select(c, HwMode::Adaptive).unwrap();
        let kern = selector.kernel(&sel).clone();
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        b.run_flops(
            &format!("real_gemm/{}x{}x{} block {:?}", m, n, k, kern.l1),
            flops,
            || {
                black_box(
                    engine
                        .gemm_dynamic(&a, &bmat, (m, n, k), kern.l1.to3(), DType::F32)
                        .unwrap(),
                );
            },
        );
    }

    // Single-block launch latency (the empirical-profiling primitive).
    b.run("real_gemm/single_block_8x128x128", || {
        black_box(engine.time_artifact("gemm_acc_8x128x128_f32", 1).unwrap());
    });
}
