//! Bench: Algorithm-2 candidate generation per testbed (the offline
//! stage's first phase). Run with `cargo bench --bench candgen`.

use vortex::candgen;
use vortex::hw::presets;
use vortex::ir::{DType, OpKind};
use vortex::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::default();
    for (name, hw, dt) in [
        ("candgen/xeon_f32", presets::xeon_8255c(), DType::F32),
        ("candgen/a100_cc_f32", presets::a100(), DType::F32),
        ("candgen/a100_tc_f16", presets::a100(), DType::F16),
        ("candgen/cpu_pjrt_f32", presets::cpu_pjrt(), DType::F32),
    ] {
        let set = candgen::generate(&hw, OpKind::Gemm, dt);
        b.run(&format!("{name} ({} cands)", set.total()), || {
            black_box(candgen::generate(&hw, OpKind::Gemm, dt));
        });
    }

    // The 4-axis batched-GEMM space (operator-generic candgen).
    let hw = presets::a100();
    let set = candgen::generate(&hw, OpKind::BatchedGemm, DType::F16);
    b.run(&format!("candgen/a100_bgemm_f16 ({} cands)", set.total()), || {
        black_box(candgen::generate(&hw, OpKind::BatchedGemm, DType::F16));
    });
}
