//! Bench: Algorithm-2 candidate generation per testbed (the offline
//! stage's first phase). Run with `cargo bench --bench candgen`.

use vortex::candgen;
use vortex::hw::presets;
use vortex::ir::DType;
use vortex::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::default();
    for (name, hw, dt) in [
        ("candgen/xeon_f32", presets::xeon_8255c(), DType::F32),
        ("candgen/a100_cc_f32", presets::a100(), DType::F32),
        ("candgen/a100_tc_f16", presets::a100(), DType::F16),
        ("candgen/cpu_pjrt_f32", presets::cpu_pjrt(), DType::F32),
    ] {
        let set = candgen::generate(&hw, dt);
        b.run(&format!("{name} ({} cands)", set.total()), || {
            black_box(candgen::generate(&hw, dt));
        });
    }
}
