//! Bench: the runtime selection hot path (Fig. 14's scheduling
//! component) — shape -> micro-kernel over the compiled library.
//! Target (EXPERIMENTS.md §Perf): well under the smallest kernel's
//! execution time. Run with `cargo bench --bench runtime_select`.

use vortex::bench::harness::{vortex_engine, Engine, Testbed};
use vortex::coordinator::HwMode;
use vortex::ir::{Contraction, DType};
use vortex::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::default();
    for tb in [Testbed::GpuTensorCore, Testbed::GpuCudaCore, Testbed::Cpu] {
        let engine = vortex_engine(tb, 7);
        let Engine::Vortex { selector, mode } = &engine else { unreachable!() };
        let nk: usize = selector.libraries.iter().map(|l| l.kernels.len()).sum();
        let shapes = [
            (1usize, 768usize, 768usize),
            (77, 2304, 768),
            (512, 3072, 768),
            (4096, 4096, 4096),
            (300_000, 16, 64),
        ];
        let stats = b.run(
            &format!("select/{} x{} shapes ({} kernels)", tb.label(), shapes.len(), nk),
            || {
                for &(m, n, k) in &shapes {
                    let c = Contraction { m, n, k, dtype: tb.dtype() };
                    black_box(selector.select(c, *mode).unwrap());
                }
            },
        );
        println!(
            "      per-selection median: {:?}",
            stats.median / shapes.len() as u32
        );
    }

    // The paper's Fig. 16 adaptive mode (two libraries scanned).
    let engine = vortex_engine(Testbed::GpuTensorCore, 7);
    let Engine::Vortex { selector, .. } = &engine else { unreachable!() };
    b.run("select/adaptive_two_backends x100", || {
        for m in 1..=100usize {
            let c = Contraction { m, n: 2048, k: 1024, dtype: DType::F16 };
            black_box(selector.select(c, HwMode::Adaptive).unwrap());
        }
    });
}
