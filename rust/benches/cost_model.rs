//! Bench: analytical cost model (Eqs. 2–4) and hybrid evaluation —
//! these run once per library kernel per selection, so they bound the
//! runtime scheduling overhead. Run with `cargo bench --bench cost_model`.

use vortex::cost::hybrid::{hybrid_cost, AnalyzerConfig};
use vortex::cost::{self, Strategy};
use vortex::hw::presets;
use vortex::ir::DType;
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;
use vortex::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::default();
    let hw = presets::a100();
    let bi = hw.backend_idx("tensor_core_f16").unwrap();
    let strat = Strategy::new(vec![[16, 8, 16], [64, 64, 32], [4096, 4096, 4096]], bi);

    b.run("cost/full_chain_eval x1000", || {
        for i in 0..1000usize {
            let mut s = strat.clone();
            s.tiles[2][0] = 4096 + (i % 7) * 64; // defeat caching
            black_box(cost::cost(&hw, DType::F16, &s, None).total_secs);
        }
    });

    b.run("cost/cost_from_level2 x1000 (runtime hot path)", || {
        for i in 0..1000usize {
            let mut s = strat.clone();
            s.tiles[2][0] = 4096 + (i % 7) * 64;
            black_box(cost::cost_from(&hw, DType::F16, &s, 2, 1e-6).total_secs);
        }
    });

    let cfg = AnalyzerConfig::empirical(1);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 3));
    // warm the measurement cache (offline behavior), then measure the
    // cached-path cost (runtime behavior).
    hybrid_cost(&hw, DType::F16, &strat, &cfg, &mut prof);
    b.run("cost/hybrid_cached x1000", || {
        for _ in 0..1000usize {
            black_box(hybrid_cost(&hw, DType::F16, &strat, &cfg, &mut prof));
        }
    });

    let sim = Simulator::new(hw.clone(), 3);
    b.run("sim/execute x1000", || {
        for i in 0..1000usize {
            let mut s = strat.clone();
            s.tiles[2][0] = 4096 + (i % 7) * 64;
            black_box(sim.execute(DType::F16, &s));
        }
    });
}
