//! Fleet determinism oracle + overload semantics — the headline tests
//! of the sharded SLO-aware serving layer.
//!
//! The contract under test: the worker-pool executor is an
//! OPTIMIZATION, not a semantics. For any trace, any replica count and
//! any worker count, `serve_fleet` must produce selections, plan
//! sources, latencies and drop/degrade decisions BITWISE identical to
//! the single-threaded discrete-event replay (`workers: 0`) of the
//! same configuration. The property test sweeps random mixed traces ×
//! replica counts {1,2,4,8} × routing policies × SLO policies; worker
//! counts come from `VORTEX_TEST_WORKERS` (comma-separated) so CI can
//! pin the matrix {1,2,8} independently of `RUST_TEST_THREADS`.
//!
//! Overload semantics ride along: a saturating burst must show
//! monotone non-increasing p99 as replicas are added, exact
//! `admitted + degraded + dropped == offered` accounting, zero drops
//! once deadlines are feasible — and the deadline-derived batching
//! window (the fix for the SLO-blind hardcoded 2 ms window) must keep
//! a tight-SLO lane from batching past its deadline budget.

use std::collections::HashMap;

use vortex::coordinator::{HwMode, Selector};
use vortex::hw::presets;
use vortex::ir::{DType, TensorProgram};
use vortex::serve::{
    scenario, serve_fleet, FleetConfig, FleetStats, LaneSlo, OverloadPolicy, RoutePolicy,
    ServeRequest, SimLaneEngine, BATCH_BUDGET_FRACTION,
};
use vortex::sim::Simulator;
use vortex::util::prop::{forall, prop_assert};

fn engine() -> SimLaneEngine {
    SimLaneEngine { sim: Simulator::new(presets::a100(), 11) }
}

/// Worker counts the equivalence suite checks against the sequential
/// oracle. CI pins one count per matrix leg via `VORTEX_TEST_WORKERS`;
/// locally the default sweeps the full {1, 2, 8} set.
fn worker_counts() -> Vec<usize> {
    match std::env::var("VORTEX_TEST_WORKERS") {
        Ok(v) => v
            .split(',')
            .map(|s| s.trim().parse().expect("VORTEX_TEST_WORKERS: usize list"))
            .collect(),
        Err(_) => vec![1, 2, 8],
    }
}

/// EVERYTHING observable about a fleet run, bit-exact: per-request
/// outcome (plan identity, source, replica, batch, launch/latency
/// bits, degrade flag) and per-drop decision (instant + miss bits).
/// Two runs with equal fingerprints are indistinguishable to a client.
#[allow(clippy::type_complexity)]
fn fingerprint(
    stats: &FleetStats,
) -> (
    Vec<(u64, usize, &'static str, usize, String, bool, u64, u64, usize, usize, String, u64)>,
    Vec<(u64, usize, &'static str, u64, u64)>,
) {
    let outcomes = stats
        .outcomes
        .iter()
        .map(|o| {
            (
                o.id,
                o.replica,
                o.lane.name(),
                o.batch_size,
                format!("{:?}", o.source),
                o.degraded,
                o.latency.to_bits(),
                o.launch.to_bits(),
                o.selection.lib,
                o.selection.kernel,
                format!("{:?} {:?}", o.selection.padded, o.selection.grid),
                o.selection.est_secs.to_bits(),
            )
        })
        .collect();
    let drops = stats
        .drops
        .iter()
        .map(|d| (d.id, d.replica, d.lane.name(), d.decided_at.to_bits(), d.miss_by.to_bits()))
        .collect();
    (outcomes, drops)
}

/// One generated oracle case: trace shape × fleet shape × SLO policy.
#[derive(Debug)]
struct OracleCase {
    trace_seed: u64,
    n_requests: usize,
    mean_gap: f64,
    replicas: usize,
    routing: RoutePolicy,
    dispatch: bool,
    slo: Option<LaneSlo>,
}

fn fleet_config(case: &OracleCase, workers: usize) -> FleetConfig {
    let mut serve = match case.slo {
        Some(slo) => scenario::slo_serving_config(slo),
        None => scenario::serving_config(),
    };
    if case.dispatch {
        // A slimmer cell budget than the scenario default keeps the
        // per-case offline build cheap; clamped horizons just shift
        // requests to the cache tier — still fully deterministic.
        let mut d = scenario::dispatch_config();
        d.max_cells = 1 << 16;
        serve = serve.with_dispatch(d);
    }
    FleetConfig { replicas: case.replicas, workers, routing: case.routing, serve }
}

/// THE headline property: the worker pool is unobservable. Every
/// worker count reproduces the sequential discrete-event replay
/// bit-for-bit — selections, plan sources, drop decisions, latencies —
/// across replica counts {1,2,4,8}, both routing policies, dispatch
/// tables on/off and all three overload policies. Failing cases
/// replay from the reported seed; `forall` sizes grow so the first
/// failure is already small.
#[test]
fn executor_matches_the_discrete_event_oracle() {
    let selector = scenario::demo_selector(5);
    let workers = worker_counts();
    forall(
        "fleet-executor-equivalence",
        9,
        0xf1ee7,
        |rng, size| OracleCase {
            trace_seed: rng.next_u64(),
            n_requests: 48 + size,
            // Spans light load to heavy overload.
            mean_gap: [4e-4, 1e-4, 2e-5][rng.usize(0, 2)],
            replicas: [1, 2, 4, 8][rng.usize(0, 3)],
            routing: [RoutePolicy::HashKey, RoutePolicy::LeastLoaded][rng.usize(0, 1)],
            dispatch: rng.usize(0, 2) == 0,
            slo: match rng.usize(0, 2) {
                0 => None,
                1 => Some(
                    LaneSlo::with_deadline(3e-4).with_policy(OverloadPolicy::Drop),
                ),
                _ => Some(LaneSlo::with_deadline(3e-4).with_policy(
                    OverloadPolicy::Degrade(HwMode::Only("cuda_core_f32")),
                )),
            },
        },
        |case| {
            let trace = scenario::mixed_trace(
                case.n_requests,
                case.mean_gap,
                case.trace_seed,
                DType::F32,
            );
            let oracle =
                serve_fleet(engine, &selector, &fleet_config(case, 0), &trace);
            prop_assert(
                oracle.offered() == trace.len(),
                format!("oracle lost requests: {} of {}", oracle.offered(), trace.len()),
            )?;
            let want = fingerprint(&oracle);
            for &w in &workers {
                let pooled =
                    serve_fleet(engine, &selector, &fleet_config(case, w), &trace);
                let got = fingerprint(&pooled);
                prop_assert(
                    got == want,
                    format!("workers={w} diverged from the sequential oracle"),
                )?;
            }
            // Tracing leg: span recording must be unobservable —
            // a traced sequential run reproduces the untraced oracle
            // bit-for-bit (the zero-perturbation contract of
            // `ServeConfig::trace`).
            let mut traced_cfg = fleet_config(case, 0);
            traced_cfg.serve = traced_cfg.serve.traced();
            let traced = serve_fleet(engine, &selector, &traced_cfg, &trace);
            prop_assert(
                fingerprint(&traced) == want,
                "tracing-on diverged from the untraced oracle".to_string(),
            )?;
            Ok(())
        },
    );
}

/// The tracing contract, explicitly at every CI worker count: enabling
/// span recording changes NOTHING about serving (same fingerprint as
/// the untraced sequential oracle), the recorded trace is non-empty
/// and identical across worker counts, and it passes the trace-schema
/// audit cleanly.
#[test]
fn tracing_on_is_bit_identical_to_tracing_off() {
    use vortex::analysis::audit_trace;
    let selector = scenario::demo_selector(5);
    let trace = scenario::mixed_trace(96, 1e-4, 17, DType::F32);
    let slo = LaneSlo::with_deadline(3e-4).with_policy(OverloadPolicy::Drop);
    let cfg = |workers: usize, traced: bool| {
        let mut d = scenario::dispatch_config();
        d.max_cells = 1 << 16;
        let mut serve = scenario::slo_serving_config(slo).with_dispatch(d);
        if traced {
            serve = serve.traced();
        }
        FleetConfig { replicas: 4, workers, routing: RoutePolicy::HashKey, serve }
    };
    let plain = serve_fleet(engine, &selector, &cfg(0, false), &trace);
    assert!(plain.trace.is_none(), "untraced runs must not carry a trace");
    let want = fingerprint(&plain);
    let mut spans_at: Option<usize> = None;
    for w in worker_counts() {
        let run = serve_fleet(engine, &selector, &cfg(w, true), &trace);
        assert_eq!(
            fingerprint(&run),
            want,
            "tracing perturbed serving at workers={w}"
        );
        let t = run.trace.as_ref().expect("trace requested");
        assert!(!t.is_empty(), "traced run recorded no spans");
        // Fixed unit-order assembly: the span stream is identical in
        // shape at every worker count.
        match spans_at {
            None => spans_at = Some(t.spans.len()),
            Some(n) => assert_eq!(t.spans.len(), n, "span count varies with workers={w}"),
        }
        let report = audit_trace(t);
        assert!(
            report.is_clean(true),
            "trace-schema audit found problems at workers={w}: {:?}",
            report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn overload_p99_is_monotone_non_increasing_in_replicas() {
    // A burst that saturates every lane: adding replicas splits the
    // queue under balanced routing, and per-batch throughput is
    // unchanged, so the tail must not get WORSE with more hardware.
    let selector = scenario::demo_selector(5);
    let trace = scenario::burst_trace(160, 21, DType::F32);
    let mut prev = f64::INFINITY;
    for replicas in [1usize, 2, 4] {
        let cfg = FleetConfig {
            replicas,
            routing: RoutePolicy::LeastLoaded,
            serve: scenario::serving_config(),
            ..FleetConfig::default()
        };
        let stats = serve_fleet(engine, &selector, &cfg, &trace);
        assert_eq!(stats.count(), trace.len());
        let (_, _, p99) = stats.latency_percentiles();
        assert!(
            p99 <= prev,
            "p99 regressed when adding replicas: {replicas} replicas -> {p99:.6e}s \
             (previous {prev:.6e}s)"
        );
        prev = p99;
    }
}

#[test]
fn overload_drop_accounting_is_exact() {
    // Tight deadlines + Drop policy on a saturating burst: the
    // admission controller MUST shed, and every request must be
    // accounted for exactly once — admitted, degraded or dropped.
    let selector = scenario::demo_selector(5);
    let trace = scenario::burst_trace(160, 23, DType::F32);
    let slo = LaneSlo::with_deadline(2e-4).with_policy(OverloadPolicy::Drop);
    let cfg = FleetConfig {
        replicas: 2,
        serve: scenario::slo_serving_config(slo),
        ..FleetConfig::default()
    };
    let stats = serve_fleet(engine, &selector, &cfg, &trace);
    assert_eq!(stats.offered(), trace.len());
    assert_eq!(
        stats.admitted() + stats.degraded() + stats.drops.len(),
        stats.offered(),
        "accounting identity violated"
    );
    assert!(!stats.drops.is_empty(), "saturating burst shed nothing");
    assert_eq!(stats.degraded(), 0, "Drop policy never degrades");
    // Per-lane Metrics counters agree with the fleet drop log.
    let metric_drops: u64 = stats
        .replicas
        .iter()
        .flat_map(|r| r.lanes.iter())
        .map(|l| l.metrics.dropped)
        .sum();
    assert_eq!(metric_drops as usize, stats.drops.len());
    // Every drop decision is self-consistent: past-deadline by > 0.
    for d in &stats.drops {
        assert!(d.miss_by > 0.0, "request {} dropped before its deadline", d.id);
    }
    // Dropped ids and served ids partition the trace.
    let mut ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
    ids.extend(stats.drops.iter().map(|d| d.id));
    ids.sort_unstable();
    assert_eq!(ids, (0..trace.len() as u64).collect::<Vec<_>>());
}

#[test]
fn feasible_deadlines_never_drop() {
    // The same burst under a deadline that comfortably covers the full
    // drain time: the Drop policy must shed NOTHING, and the SLO audit
    // must agree the deadline is feasible.
    let selector = scenario::demo_selector(5);
    let trace = scenario::burst_trace(160, 23, DType::F32);
    let slo = LaneSlo::with_deadline(10.0).with_policy(OverloadPolicy::Drop);
    let cfg = FleetConfig {
        replicas: 2,
        serve: scenario::slo_serving_config(slo),
        ..FleetConfig::default()
    };
    let stats = serve_fleet(engine, &selector, &cfg, &trace);
    assert!(stats.drops.is_empty(), "feasible deadline still shed {:?}", stats.drops);
    assert_eq!(stats.count(), trace.len());
    assert!(
        stats.slo_diags.is_empty(),
        "audit flagged a feasible config: {:?}",
        stats.slo_diags.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
}

#[test]
fn degrade_policy_downgrades_instead_of_dropping() {
    let selector = scenario::demo_selector(5);
    let trace = scenario::burst_trace(160, 23, DType::F32);
    let slo = LaneSlo::with_deadline(2e-4)
        .with_policy(OverloadPolicy::Degrade(HwMode::Only("cuda_core_f32")));
    let cfg = FleetConfig {
        replicas: 1,
        serve: scenario::slo_serving_config(slo),
        ..FleetConfig::default()
    };
    let stats = serve_fleet(engine, &selector, &cfg, &trace);
    // Nothing is lost: degraded requests still execute.
    assert_eq!(stats.count(), trace.len());
    assert!(stats.drops.is_empty(), "Degrade policy never sheds");
    assert!(stats.degraded() > 0, "saturating burst never degraded");
    assert_eq!(stats.admitted() + stats.degraded(), stats.offered());
    // Degraded batches close immediately: launch == the batch open
    // instant, which is never before arrival.
    for o in stats.outcomes.iter().filter(|o| o.degraded) {
        assert!(o.launch >= 0.0 && o.latency > 0.0);
    }
}

#[test]
fn tight_slo_lane_never_batches_past_its_deadline_budget() {
    // Satellite fix: the hardcoded 2 ms batch window used to ignore
    // SLOs entirely. Under a 400 µs deadline the effective window is
    // 100 µs (BATCH_BUDGET_FRACTION), so on an underloaded trace — the
    // server is always free when a request arrives — no request may
    // wait in the batcher past its deadline budget.
    let selector = scenario::demo_selector(5);
    let deadline = 4e-4;
    // Deterministically underloaded: the burst templates (all four
    // lanes) re-spaced 3 ms apart — far beyond any single batch's
    // service time, so every batch head finds the server free and the
    // only wait left is the batcher's own window.
    let mut trace = scenario::burst_trace(60, 31, DType::F32);
    for (i, r) in trace.iter_mut().enumerate() {
        r.arrive = i as f64 * 3e-3;
    }
    let cfg = FleetConfig {
        serve: scenario::slo_serving_config(LaneSlo::with_deadline(deadline)),
        ..FleetConfig::default()
    };
    let stats = serve_fleet(engine, &selector, &cfg, &trace);
    assert_eq!(stats.count(), trace.len());
    let arrive: HashMap<u64, f64> = trace.iter().map(|r| (r.id, r.arrive)).collect();
    for o in &stats.outcomes {
        let waited = o.launch - arrive[&o.id];
        assert!(
            waited <= deadline * BATCH_BUDGET_FRACTION + 1e-12,
            "request {} waited {:.3e}s in the batcher (> budget {:.3e}s)",
            o.id,
            waited,
            deadline * BATCH_BUDGET_FRACTION
        );
    }
}

#[test]
fn slo_window_fix_changes_batching_where_the_old_window_overshot() {
    // Two merge-compatible requests 1.5 ms apart, nothing else. Under
    // the legacy 2 ms window the head waits for the peer and launches
    // at 1.5 ms; under a 400 µs deadline the window caps at 100 µs, so
    // the head launches alone at its budget and the peer rides the
    // next batch — the regression the satellite fix pins.
    let selector = scenario::demo_selector(5);
    let gemm = TensorProgram::Gemm { m: 64, n: 2304, k: 768, dtype: DType::F32 };
    let trace = vec![
        ServeRequest { id: 0, program: gemm.clone(), arrive: 0.0, steps: 1 },
        ServeRequest { id: 1, program: gemm, arrive: 1.5e-3, steps: 1 },
    ];

    let legacy = FleetConfig { serve: scenario::serving_config(), ..FleetConfig::default() };
    let old = serve_fleet(engine, &selector, &legacy, &trace);
    assert_eq!(old.outcomes[0].batch_size, 2, "legacy window should merge the pair");
    assert!(old.outcomes[0].launch >= 1.5e-3, "legacy head launches with the peer");

    let slo = FleetConfig {
        serve: scenario::slo_serving_config(LaneSlo::with_deadline(4e-4)),
        ..FleetConfig::default()
    };
    let new = serve_fleet(engine, &selector, &slo, &trace);
    assert_eq!(new.outcomes[0].batch_size, 1, "tight SLO must not wait for the peer");
    assert!(
        new.outcomes[0].launch <= 4e-4 * BATCH_BUDGET_FRACTION + 1e-12,
        "head launched at {:.3e}s, past its batching budget",
        new.outcomes[0].launch
    );
}

#[test]
fn replica_sharding_is_deterministic_across_worker_counts_on_a_burst() {
    // The oracle property on the OVERLOAD path specifically: drops and
    // degraded flags are scheduling-sensitive in a naive
    // implementation (they depend on the event clock), so the burst +
    // tight-SLO case gets its own explicit equivalence check at every
    // CI worker count.
    let selector = scenario::demo_selector(5);
    let trace = scenario::burst_trace(120, 29, DType::F32);
    for slo in [
        LaneSlo::with_deadline(2e-4).with_policy(OverloadPolicy::Drop),
        LaneSlo::with_deadline(2e-4)
            .with_policy(OverloadPolicy::Degrade(HwMode::Only("cuda_core_f32"))),
    ] {
        for replicas in [2usize, 8] {
            let cfg = |workers| FleetConfig {
                replicas,
                workers,
                routing: RoutePolicy::HashKey,
                serve: scenario::slo_serving_config(slo),
            };
            let oracle = serve_fleet(engine, &selector, &cfg(0), &trace);
            let want = fingerprint(&oracle);
            for w in worker_counts() {
                let pooled = serve_fleet(engine, &selector, &cfg(w), &trace);
                assert_eq!(
                    fingerprint(&pooled),
                    want,
                    "workers={w} replicas={replicas} diverged on the overload path"
                );
            }
        }
    }
}

#[test]
fn decode_lane_replays_bit_identically_across_worker_counts() {
    // The acceptance property for the continuous-batching decode lane:
    // autoregressive sequences woven into one-shot mixed traffic
    // replay bit-identically under the worker pool at every CI worker
    // count. The decode lane is the scheduling-sensitive case — slot
    // reuse, step-boundary admission and per-token metrics all depend
    // on the event clock — so it gets its own explicit equivalence
    // check on top of the headline forall. The FULL dispatch budget
    // (not the slimmed oracle budget) keeps the tentpole invariant
    // visible in the fingerprint: `source` records the worst tier any
    // token paid, so every decode outcome must read `Table`.
    let selector = scenario::demo_selector(5);
    let mut trace = scenario::mixed_trace(48, 2e-4, 41, DType::F32);
    let mut decode = scenario::decode_trace(32, 4e-4, 16, 43, DType::F32);
    for r in &mut decode {
        r.id += 10_000;
    }
    trace.extend(decode);
    trace.sort_by(|a, b| a.arrive.partial_cmp(&b.arrive).unwrap());
    for replicas in [1usize, 8] {
        let cfg = |workers| FleetConfig {
            replicas,
            workers,
            routing: RoutePolicy::HashKey,
            serve: scenario::serving_config().with_dispatch(scenario::dispatch_config()),
        };
        let oracle = serve_fleet(engine, &selector, &cfg(0), &trace);
        assert_eq!(oracle.count(), trace.len());
        let mut decoded = 0usize;
        for o in oracle.outcomes.iter().filter(|o| o.id >= 10_000) {
            decoded += 1;
            assert_eq!(
                format!("{:?}", o.source),
                "Table",
                "decode sequence {} left the table tier at replicas={replicas}",
                o.id
            );
        }
        assert_eq!(decoded, 32, "every decode sequence completes");
        let want = fingerprint(&oracle);
        for w in worker_counts() {
            let pooled = serve_fleet(engine, &selector, &cfg(w), &trace);
            assert_eq!(
                fingerprint(&pooled),
                want,
                "workers={w} replicas={replicas} diverged on the decode lane"
            );
        }
    }
}

/// Keep `Selector` usable from the closure the pool shares — a compile
/// check in test form: the fleet API must stay callable with a plain
/// borrowed selector and a plain `fn` engine factory (no `Arc`
/// ceremony), or downstream embedding gets painful.
#[test]
fn fleet_api_accepts_plain_borrows_and_fn_factories() {
    let selector: Selector = scenario::demo_selector(5);
    let trace = scenario::mixed_trace(60, 4e-4, 3, DType::F32);
    let cfg = FleetConfig { workers: 2, ..FleetConfig::default() };
    let stats = serve_fleet(engine, &selector, &cfg, &trace);
    assert_eq!(stats.count(), trace.len());
}
