//! Cross-layer invariant: every gemm block pinned in the PYTHON
//! micro-kernel manifest must be a tile the RUST candidate generator
//! (Algorithm 2) actually produces for the real testbed — the manifest
//! is a checked-in snapshot of candgen output, not a hand-rolled list.

use std::path::PathBuf;

use vortex::candgen;
use vortex::hw::{presets, HwSpec};
use vortex::ir::{DType, OpKind, Tile};
use vortex::util::json::Json;

fn manifest_json() -> Option<Json> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("python/compile/microkernels.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("manifest must parse"))
}

fn blocks_of(kind_filter: &str, dtype: &str) -> Vec<[usize; 3]> {
    let m = manifest_json().expect("microkernels.json present");
    m.get("entries")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("kind").unwrap().as_str() == Some(kind_filter))
        .filter(|e| {
            e.get("params")
                .and_then(|p| p.get("in_dtype"))
                .and_then(|d| d.as_str())
                .unwrap_or("f32")
                == dtype
        })
        .map(|e| {
            let p = e.get("params").unwrap();
            [
                p.get("bm").unwrap().as_usize().unwrap(),
                p.get("bn").unwrap().as_usize().unwrap(),
                p.get("bk").unwrap().as_usize().unwrap(),
            ]
        })
        .collect()
}

#[test]
fn manifest_gemm_blocks_are_candgen_valid() {
    let hw = presets::cpu_pjrt();
    for (dtype_name, dtype) in [("f32", DType::F32), ("bf16", DType::Bf16)] {
        let set = candgen::generate(&hw, OpKind::Gemm, dtype);
        let bi = hw
            .backend_idx(if dtype == DType::F32 { "mxu_f32" } else { "mxu_bf16" })
            .unwrap();
        let backend = &hw.backends[bi];
        for block in blocks_of("gemm_acc", dtype_name) {
            // ISA granularity (FilterByISA).
            for (t, g) in block.iter().zip(backend.isa.iter()) {
                assert_eq!(t % g, 0, "{dtype_name} block {:?} ISA-misaligned", block);
            }
            // Capacity at the staging tier.
            let ws = HwSpec::gemm_working_set(block, backend.dtype_bytes);
            assert!(
                ws <= hw.level(1).capacity_bytes,
                "{dtype_name} block {:?} spills the staging tier ({} B)",
                block,
                ws
            );
            // Producible by Algorithm 2 at L1 or at least L0 (very small
            // blocks fall below the L1 utilization window but remain
            // valid L0/dot-tier tiles).
            let in_l1 = set.levels[1].iter().any(|c| c.tile == Tile::from3(block));
            let fits_l0 = ws <= hw.level(0).capacity_bytes;
            assert!(
                in_l1 || fits_l0,
                "{dtype_name} block {:?} not producible by candgen",
                block
            );
        }
    }
}

#[test]
fn manifest_inner_tiles_equal_blocks() {
    // EXPERIMENTS.md §Perf L1: on this testbed tile = block.
    let m = manifest_json().expect("microkernels.json present");
    for e in m.get("entries").unwrap().as_arr().unwrap() {
        if e.get("kind").unwrap().as_str() != Some("gemm_acc") {
            continue;
        }
        let p = e.get("params").unwrap();
        for (b, t) in [("bm", "tm"), ("bn", "tn"), ("bk", "tk")] {
            assert_eq!(
                p.get(b).unwrap().as_usize(),
                p.get(t).unwrap().as_usize(),
                "{}: inner tile != block",
                e.get("name").unwrap().as_str().unwrap()
            );
        }
    }
}

#[test]
fn manifest_names_follow_artifact_convention() {
    let m = manifest_json().expect("microkernels.json present");
    for e in m.get("entries").unwrap().as_arr().unwrap() {
        if e.get("kind").unwrap().as_str() != Some("gemm_acc") {
            continue;
        }
        let p = e.get("params").unwrap();
        let expect = format!(
            "gemm_acc_{}x{}x{}_{}",
            p.get("bm").unwrap().as_usize().unwrap(),
            p.get("bn").unwrap().as_usize().unwrap(),
            p.get("bk").unwrap().as_usize().unwrap(),
            p.get("in_dtype").and_then(|d| d.as_str()).unwrap_or("f32"),
        );
        assert_eq!(e.get("name").unwrap().as_str(), Some(expect.as_str()));
    }
}
