//! Cross-module integration tests on the simulated testbeds: the full
//! offline -> runtime pipeline and the paper's headline claims as
//! executable assertions.

use vortex::baselines::dietcode::DietCode;
use vortex::baselines::vendor::VendorLib;
use vortex::baselines::PlanEngine;
use vortex::bench::harness::{baseline_engines, vortex_engine, Engine, Testbed};
use vortex::bench::workloads;
use vortex::compiler::{compile, CompileOpts, MicroKernelLibrary};
use vortex::coordinator::{HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{Contraction, DType, OpKind};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;
use vortex::util::prop::{forall, prop_assert};

fn gemm(m: usize, n: usize, k: usize) -> Contraction {
    Contraction { m, n, k, dtype: DType::F32 }
}

#[test]
fn headline_vortex_beats_vendor_on_majority_of_dynamic_shapes() {
    // Table 5's core claim, as a test: on the transformer shape suite,
    // Vortex wins the majority of cases against the vendor library.
    let tb = Testbed::GpuCudaCore;
    let sim = Simulator::new(tb.hw(), 11);
    let vortex = vortex_engine(tb, 11);
    let cublas = VendorLib::cublas(&tb.hw(), "cuda_core_f32");
    let mut wins = 0;
    let mut total = 0;
    for case in workloads::gemm_suite(DType::F32, 11).iter().step_by(4) {
        if case.category != "transformer" {
            continue;
        }
        let c = case.program.contraction();
        let tv = vortex.time(&sim, c);
        let tc = sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead();
        total += 1;
        if tv < tc {
            wins += 1;
        }
    }
    assert!(total >= 20);
    assert!(
        wins * 10 >= total * 7,
        "vortex won only {wins}/{total} transformer cases"
    );
}

#[test]
fn headline_sample_free_offline_is_orders_faster_than_dietcode() {
    // The 176x offline-speedup claim, directionally: Vortex's modeled
    // offline time on GPU-CC must be >=20x smaller than DietCode's
    // tuning time at a realistic trial budget.
    let hw = presets::a100();
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 3));
    let vortex = compile(
        &hw,
        OpKind::Gemm,
        DType::F32,
        &AnalyzerConfig::default_for(&hw),
        &mut prof,
        &CompileOpts::default(),
    );
    let mut prof2 = SimProfiler::new(Simulator::new(hw.clone(), 3));
    // Paper setup: the whole Table-3 suite is DietCode's sample list.
    let samples: Vec<[usize; 3]> = workloads::gemm_suite(DType::F32, 3)
        .iter()
        .map(|c| {
            let ct = c.program.contraction();
            [ct.m, ct.n, ct.k]
        })
        .collect();
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 400, &mut prof2, 3);
    assert!(
        dc.tuning_secs > 20.0 * vortex.offline_secs,
        "dietcode {} !>> vortex {}",
        dc.tuning_secs,
        vortex.offline_secs
    );
}

#[test]
fn dietcode_out_of_sample_degrades() {
    // Fig. 3 / Table 6 geometry: DietCode's own performance on shapes
    // far from its samples is worse (per-flop) than at its samples.
    let hw = presets::a100();
    let sim = Simulator::new(hw.clone(), 5);
    let mut prof = SimProfiler::new(sim.clone());
    let samples: Vec<[usize; 3]> =
        [128usize, 160, 192, 224].iter().map(|&m| [m, 768, 2304]).collect();
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 200, &mut prof, 5);
    let per_flop = |m: usize| {
        let c = gemm(m, 768, 2304);
        sim.execute(DType::F32, &dc.plan(c)) / c.flops()
    };
    // In-sample average vs far-out-of-sample average (small M pays
    // padding up to the nearest sample's tile).
    let in_s = (per_flop(128) + per_flop(192)) / 2.0;
    let out_s = (per_flop(5) + per_flop(24) + per_flop(43)) / 3.0;
    assert!(
        out_s > 1.5 * in_s,
        "out-of-sample per-flop {} !> 1.5x in-sample {}",
        out_s,
        in_s
    );
}

#[test]
fn vortex_is_flat_where_dietcode_saws() {
    // Vortex's per-flop cost across the same M sweep must vary much
    // less than DietCode's (the sample-free flatness claim).
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), 5);
    let vortex = vortex_engine(tb, 5);
    let mut prof = SimProfiler::new(sim.clone());
    let samples: Vec<[usize; 3]> =
        [128usize, 192].iter().map(|&m| [m, 768, 2304]).collect();
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 200, &mut prof, 5);
    let spread = |f: &dyn Fn(usize) -> f64| {
        let vals: Vec<f64> = (1..=12).map(|i| f(i * 32)).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        max / min
    };
    let v_spread = spread(&|m| vortex.time(&sim, gemm(m, 768, 2304)) / gemm(m, 768, 2304).flops());
    let d_spread = spread(&|m| {
        sim.execute(DType::F32, &dc.plan(gemm(m, 768, 2304))) / gemm(m, 768, 2304).flops()
    });
    assert!(
        v_spread < d_spread,
        "vortex per-flop spread {} !< dietcode {}",
        v_spread,
        d_spread
    );
}

#[test]
fn library_round_trips_through_disk() {
    let hw = presets::a100();
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 1));
    let lib = compile(
        &hw,
        OpKind::Gemm,
        DType::F16,
        &AnalyzerConfig::default_for(&hw),
        &mut prof,
        &CompileOpts::default(),
    )
    .library;
    let path = std::env::temp_dir().join("vortex_lib_roundtrip.json");
    std::fs::write(&path, lib.to_json().dump()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = vortex::util::json::Json::parse(&text).unwrap();
    let lib2 = MicroKernelLibrary::from_json(&parsed).unwrap();
    assert_eq!(lib.kernels, lib2.kernels);

    // And a selector built from the reloaded library behaves identically.
    let s1 = Selector::new(hw.clone(), vec![lib]);
    let s2 = Selector::new(hw.clone(), vec![lib2]);
    for &(m, n, k) in &[(7usize, 768usize, 768usize), (512, 512, 512)] {
        let c = Contraction { m, n, k, dtype: DType::F16 };
        let a = s1.select(c, HwMode::Adaptive).unwrap();
        let b = s2.select(c, HwMode::Adaptive).unwrap();
        assert_eq!(s1.kernel(&a).l1, s2.kernel(&b).l1);
    }
}

#[test]
fn prop_every_engine_covers_every_shape() {
    // Sample-free coverage: all engines must produce a valid plan for
    // ANY shape (no panics, sane padding) — Vortex via selection,
    // baselines via their dispatchers.
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), 13);
    let vortex = vortex_engine(tb, 13);
    let baselines = baseline_engines(tb, false, 13);
    forall(
        "all-engines-cover-all-shapes",
        40,
        0xA11,
        |r, size| {
            (
                r.usize(1, 1 + size * 100),
                r.usize(1, 1 + size * 40),
                r.usize(1, 1 + size * 40),
            )
        },
        |&(m, n, k)| {
            let c = gemm(m, n, k);
            let tv = vortex.time(&sim, c);
            prop_assert(tv.is_finite() && tv > 0.0, "vortex time invalid")?;
            for b in &baselines {
                let t = b.time(&sim, c);
                prop_assert(
                    t.is_finite() && t > 0.0,
                    format!("{} time invalid for {:?}", b.name(), (m, n, k)),
                )?;
                if let Engine::Baseline(p) = b {
                    let plan = p.plan(c);
                    let top = plan.tiles[2];
                    prop_assert(
                        top[0] >= m && top[1] >= n && top[2] >= k,
                        format!("{} under-padded {:?}", p.name(), top),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn adaptive_mode_crossover_exists() {
    // Fig. 16: there must exist small-M cases where CUDA cores win and
    // larger-M cases where tensor cores win, and Adaptive tracks both.
    let engine = vortex_engine(Testbed::GpuTensorCore, 7);
    let Engine::Vortex { selector, .. } = &engine else { unreachable!() };
    let sim = Simulator::new(presets::a100(), 7);
    let time = |m: usize, n: usize, mode: HwMode| {
        let c = Contraction { m, n, k: 1024, dtype: DType::F16 };
        let sel = selector.select(c, mode).unwrap();
        sim.execute(selector.libraries[sel.lib].dtype, &selector.chain(&sel))
    };
    let mut cc_wins = 0;
    let mut tc_wins = 0;
    let mut ad_beats_cc = false;
    let mut ad_beats_tc = false;
    for &n in &[1024usize, 2048, 4096] {
        for m in [1usize, 2, 4, 8, 12, 16] {
            let cc = time(m, n, HwMode::Only("cuda_core_f32"));
            let tc = time(m, n, HwMode::Only("tensor_core_f16"));
            let ad = time(m, n, HwMode::Adaptive);
            // Adaptive selects by ESTIMATE (as the paper's runtime does),
            // so it may occasionally trail the best fixed mode in truth —
            // but never catastrophically.
            assert!(ad <= cc.min(tc) * 1.3, "adaptive lost badly at m={m} n={n}");
            if ad < cc * 0.95 {
                ad_beats_cc = true;
            }
            if ad < tc * 0.95 {
                ad_beats_tc = true;
            }
            if cc < tc {
                cc_wins += 1;
            } else {
                tc_wins += 1;
            }
        }
    }
    assert!(cc_wins > 0, "no CUDA-core wins — no crossover to adapt over");
    assert!(tc_wins > 0, "no tensor-core wins");
    // The Fig. 16 headline: adaptive gains exist over BOTH fixed modes.
    assert!(ad_beats_cc, "adaptive never beat CUDA-core-only");
    assert!(ad_beats_tc, "adaptive never beat tensor-core-only");
}
