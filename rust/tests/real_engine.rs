//! Integration tests over the REAL path: AOT artifacts -> PJRT compile
//! -> kernel-constructor execution, cross-checked against a host GEMM.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;

use vortex::coordinator::{HwMode, Selector};
use vortex::hw::presets;
use vortex::ir::{Contraction, DType};
use vortex::runtime::{build_real_library, gemm_host_ref, RealEngine};
use vortex::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn engine() -> Option<RealEngine> {
    let dir = artifacts_dir().or_else(|| {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    })?;
    Some(RealEngine::load(&dir).expect("engine load"))
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    Rng::new(seed).normal_f32_vec(n)
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{}: length", what);
    let mut worst = 0f32;
    for (g, w) in got.iter().zip(want.iter()) {
        let d = (g - w).abs() / (1.0 + w.abs());
        worst = worst.max(d);
    }
    assert!(worst < tol, "{}: worst rel err {}", what, worst);
}

#[test]
fn manifest_loads_and_has_expected_kinds() {
    let Some(eng) = engine() else { return };
    let kinds: std::collections::BTreeSet<&str> =
        eng.manifest.entries.iter().map(|e| e.kind.as_str()).collect();
    for k in ["gemm_acc", "gemm_bias_act", "softmax", "conv2d", "encoder_layer"] {
        assert!(kinds.contains(k), "missing kind {}", k);
    }
    assert!(eng.manifest.gemm_acc_blocks(DType::F32).len() >= 10);
    assert!(eng.manifest.gemm_acc_blocks(DType::Bf16).len() >= 2);
}

#[test]
fn single_block_gemm_acc_matches_host() {
    let Some(eng) = engine() else { return };
    let (m, n, k) = (8, 128, 128);
    let a = rand_vec(m * k, 1);
    let b = rand_vec(k * n, 2);
    let c = eng
        .gemm_dynamic(&a, &b, (m, n, k), [8, 128, 128], DType::F32)
        .expect("gemm");
    assert_close(&c, &gemm_host_ref(&a, &b, m, n, k), 1e-4, "8x128x128");
}

#[test]
fn dynamic_shapes_compose_over_grid_and_k_chain() {
    let Some(eng) = engine() else { return };
    // Shapes chosen to exercise: exact fit, M padding, K chaining,
    // N tiling, and all three at once.
    for &(m, n, k) in &[
        (16usize, 128usize, 256usize), // exact block fit
        (5, 128, 128),                 // M padding
        (16, 128, 700),                // K chain with ragged tail
        (40, 300, 300),                // everything ragged
    ] {
        let a = rand_vec(m * k, 10 + m as u64);
        let b = rand_vec(k * n, 20 + n as u64);
        let block = [16, 128, 256];
        let c = eng
            .gemm_dynamic(&a, &b, (m, n, k), block, DType::F32)
            .expect("gemm");
        assert_close(
            &c,
            &gemm_host_ref(&a, &b, m, n, k),
            1e-3,
            &format!("m{}n{}k{}", m, n, k),
        );
    }
}

#[test]
fn bf16_block_matches_host_loosely() {
    let Some(eng) = engine() else { return };
    let (m, n, k) = (32, 256, 256);
    let a = rand_vec(m * k, 3);
    let b = rand_vec(k * n, 4);
    let c = eng
        .gemm_dynamic(&a, &b, (m, n, k), [32, 256, 256], DType::Bf16)
        .expect("gemm bf16");
    // bf16 inputs: ~3 decimal digits.
    assert_close(&c, &gemm_host_ref(&a, &b, m, n, k), 0.15, "bf16");
}

#[test]
fn real_library_selector_end_to_end() {
    let Some(eng) = engine() else { return };
    let hw = presets::cpu_pjrt();
    let lib = build_real_library(&eng, &hw, DType::F32, 1).expect("library");
    assert!(lib.kernels.len() >= 10);
    assert!(lib.kernels.iter().all(|k| k.base_cost > 0.0));

    let selector = Selector::new(hw, vec![lib]);
    // A BERT-ish dynamic shape: seq=77 rows.
    let c = Contraction { m: 77, n: 768, k: 768, dtype: DType::F32 };
    let sel = selector.select(c, HwMode::Adaptive).expect("select");
    let kern = selector.kernel(&sel);

    let a = rand_vec(c.m * c.k, 5);
    let b = rand_vec(c.k * c.n, 6);
    let got = eng
        .gemm_dynamic(&a, &b, (c.m, c.n, c.k), kern.l1.to3(), DType::F32)
        .expect("selected gemm");
    assert_close(
        &got,
        &gemm_host_ref(&a, &b, c.m, c.n, c.k),
        1e-3,
        "selected kernel",
    );
    // The constructed grid must cover the padded problem.
    for d in 0..3 {
        assert!(sel.grid[d] * kern.l1[d] >= [c.m, c.n, c.k][d]);
    }
}

#[test]
fn softmax_and_encoder_artifacts_execute() {
    let Some(eng) = engine() else { return };
    // softmax_128x128: rows sum to 1 after execution.
    let x = rand_vec(128 * 128, 7);
    let y = eng
        .run_raw("softmax_128x128", &[(&x, vec![128, 128])])
        .expect("softmax");
    for r in 0..128 {
        let s: f32 = y[r * 128..(r + 1) * 128].iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "row {} sums to {}", r, s);
    }

    // encoder bucket: runs and returns finite values of the right size.
    let d = 256;
    let ff = 1024;
    let seq = 64;
    let scale = |v: Vec<f32>, s: f32| -> Vec<f32> { v.into_iter().map(|x| x * s).collect() };
    let xin = rand_vec(seq * d, 8);
    let wq = scale(rand_vec(d * d, 9), 0.06);
    let wk = scale(rand_vec(d * d, 10), 0.06);
    let wv = scale(rand_vec(d * d, 11), 0.06);
    let wo = scale(rand_vec(d * d, 12), 0.06);
    let w1 = scale(rand_vec(d * ff, 13), 0.06);
    let b1 = vec![0.0f32; ff];
    let w2 = scale(rand_vec(ff * d, 14), 0.03);
    let b2 = vec![0.0f32; d];
    let out = eng
        .run_raw(
            "encoder_s64_d256",
            &[
                (&xin, vec![seq as i64, d as i64]),
                (&wq, vec![d as i64, d as i64]),
                (&wk, vec![d as i64, d as i64]),
                (&wv, vec![d as i64, d as i64]),
                (&wo, vec![d as i64, d as i64]),
                (&w1, vec![d as i64, ff as i64]),
                (&b1, vec![ff as i64]),
                (&w2, vec![ff as i64, d as i64]),
                (&b2, vec![d as i64]),
            ],
        )
        .expect("encoder");
    assert_eq!(out.len(), seq * d);
    assert!(out.iter().all(|v| v.is_finite()));
}

/// Real-path conv selector: the profiled GEMM library plus its lift
/// onto the group-batched op — the real runtime serves grouped convs
/// as a loop of gemm_acc blocks, so the lifted library's costs are the
/// honest per-group block costs.
fn conv_selector(eng: &RealEngine) -> Selector {
    use vortex::ir::OpKind;
    let hw = presets::cpu_pjrt();
    let lib = build_real_library(eng, &hw, DType::F32, 1).expect("library");
    let grouped = lib
        .lift_to_batched(OpKind::GroupedConv2d)
        .expect("gemm library lifts onto the group-batched op");
    Selector::new(hw, vec![lib, grouped])
}

#[test]
fn conv2d_dynamic_matches_direct_reference_across_the_family() {
    use vortex::runtime::{conv2d_dynamic, conv2d_host_ref};
    let Some(eng) = engine() else { return };
    let selector = conv_selector(&eng);
    // (io, filt, geom): valid, strided+padded, depthwise, grouped.
    for (io, filt, geom) in [
        ((2usize, 9usize, 9usize, 16usize), (3usize, 3usize, 32usize), (1usize, 0usize, 1usize)),
        ((2, 9, 9, 16), (3, 3, 32), (2, 1, 1)),   // ResNet-style stride
        ((1, 12, 12, 3), (5, 5, 8), (3, 2, 1)),   // coarse stride + halo
        ((2, 8, 8, 16), (3, 3, 16), (1, 1, 16)),  // depthwise
        ((1, 8, 8, 16), (3, 3, 32), (2, 1, 4)),   // grouped, strided
    ] {
        let (n, h, w, cin) = io;
        let (kh, kw, cout) = filt;
        let cg = cin / geom.2;
        let x = rand_vec(n * h * w * cin, 31 + h as u64);
        let wgt = rand_vec(kh * kw * cg * cout, 32 + cout as u64);
        let got = conv2d_dynamic(&eng, &selector, &x, &wgt, io, filt, geom, DType::F32)
            .expect("conv");
        let want = conv2d_host_ref(&x, &wgt, io, filt, geom);
        assert_close(
            &got,
            &want,
            1e-3,
            &format!("conv {:?} {:?} {:?}", io, filt, geom),
        );
    }
}

/// Real-path attention selector: the profiled GEMM library plus its
/// lift onto the batch-extended op — the attention chain then serves
/// through the BatchedGemm measurement-alias fixpoint (no native
/// attention library, no attention-specific side path).
fn attention_selector(eng: &RealEngine) -> Selector {
    use vortex::ir::OpKind;
    let hw = presets::cpu_pjrt();
    let lib = build_real_library(eng, &hw, DType::F32, 1).expect("library");
    let batched = lib
        .lift_to_batched(OpKind::BatchedGemm)
        .expect("gemm library lifts onto the batched op");
    Selector::new(hw, vec![lib, batched])
}

#[test]
fn attention_dynamic_matches_direct_reference() {
    use vortex::runtime::{attention_dynamic, attention_host_ref};
    let Some(eng) = engine() else { return };
    let selector = attention_selector(&eng);
    // (batch, seq, d, heads): decode step, ragged seq, multi-head.
    for (batch, seq, d, heads) in
        [(1usize, 1usize, 32usize, 2usize), (1, 13, 32, 2), (2, 40, 64, 4)]
    {
        let hd = d / heads;
        let len = batch * heads * seq * hd;
        let q = rand_vec(len, 41 + seq as u64);
        let k = rand_vec(len, 42 + seq as u64);
        let v = rand_vec(len, 43 + seq as u64);
        let got = attention_dynamic(
            &eng,
            &selector,
            &q,
            &k,
            &v,
            (batch, seq),
            (d, heads),
            DType::F32,
        )
        .expect("attention");
        let want = attention_host_ref(&q, &k, &v, (batch, seq), (d, heads));
        assert_close(
            &got,
            &want,
            1e-3,
            &format!("attention b{} s{} d{} h{}", batch, seq, d, heads),
        );
    }
}

#[test]
fn attention_dynamic_rejects_invalid_geometry() {
    use vortex::runtime::attention_dynamic;
    let Some(eng) = engine() else { return };
    let selector = attention_selector(&eng);
    let buf = vec![0f32; 64];
    // Heads not dividing d, zero seq: construction-time errors surfaced
    // by the runtime entry point (mirrors conv2d_dynamic).
    for (io, proj) in [((1usize, 4usize), (30usize, 4usize)), ((1, 0), (32, 4))] {
        assert!(
            attention_dynamic(&eng, &selector, &buf, &buf, &buf, io, proj, DType::F32)
                .is_err(),
            "geometry {:?} {:?} accepted",
            io,
            proj
        );
    }
}

#[test]
fn bgemm_dynamic_native_matches_per_group_loop() {
    use vortex::runtime::OperandSource;
    let Some(eng) = engine() else { return };
    if eng.manifest.bgemm_acc_blocks(DType::F32).is_empty() {
        eprintln!("SKIP: no bgemm_acc artifacts in manifest — rerun `make artifacts`");
        return;
    }
    // Ragged on every axis, batch not a multiple of bb=4: edge chunks
    // zero-pad groups past the batch, edge cells crop rows/cols.
    let (batch, m, n, k) = (6usize, 12usize, 200usize, 300usize);
    let a: Vec<Vec<f32>> = (0..batch).map(|g| rand_vec(m * k, 50 + g as u64)).collect();
    let b: Vec<Vec<f32>> = (0..batch).map(|g| rand_vec(k * n, 60 + g as u64)).collect();
    let a_srcs: Vec<OperandSource> =
        a.iter().map(|v| OperandSource::dense(v, m, k)).collect();
    let b_srcs: Vec<OperandSource> =
        b.iter().map(|v| OperandSource::dense(v, k, n)).collect();
    let got = eng
        .bgemm_dynamic(&a_srcs, &b_srcs, (m, n, k), [4, 8, 128, 128], DType::F32)
        .expect("bgemm");
    let mut want = Vec::new();
    for g in 0..batch {
        want.extend(
            eng.gemm_dynamic(&a[g], &b[g], (m, n, k), [8, 128, 128], DType::F32)
                .expect("gemm"),
        );
    }
    assert_close(&got, &want, 1e-4, "bgemm native vs per-group loop");
}

#[test]
fn real_libraries_include_profiled_batched_blocks() {
    use vortex::ir::OpKind;
    use vortex::runtime::build_real_libraries;
    let Some(eng) = engine() else { return };
    let hw = presets::cpu_pjrt();
    let libs = build_real_libraries(&eng, &hw, DType::F32, 1).expect("libraries");
    assert_eq!(libs[0].op, OpKind::Gemm);
    if eng.manifest.bgemm_acc_blocks(DType::F32).is_empty() {
        eprintln!("SKIP: no bgemm_acc artifacts — batched library not built");
        return;
    }
    let batched =
        libs.iter().find(|l| l.op == OpKind::BatchedGemm).expect("batched library");
    assert!(!batched.kernels.is_empty());
    assert!(batched.kernels.iter().all(|k| k.l1.rank() == 4 && k.base_cost > 0.0));
    // Profiled batch tiles are real blocks, not the lift's batch=1.
    assert!(batched.kernels.iter().any(|k| k.l1[0] > 1));
}

#[test]
fn conv2d_dynamic_rejects_invalid_geometry() {
    use vortex::runtime::conv2d_dynamic;
    let Some(eng) = engine() else { return };
    let selector = conv_selector(&eng);
    let x = vec![0f32; 2 * 2 * 2 * 4];
    let w = vec![0f32; 3 * 3 * 4 * 8];
    // Undersized feature map, zero stride, non-dividing groups: each is
    // a construction-time error surfaced by the runtime entry point.
    for geom in [(1usize, 0usize, 1usize), (0, 1, 1), (1, 1, 3)] {
        assert!(
            conv2d_dynamic(&eng, &selector, &x, &w, (2, 2, 2, 4), (3, 3, 8), geom, DType::F32)
                .is_err(),
            "geom {:?} accepted",
            geom
        );
    }
}
