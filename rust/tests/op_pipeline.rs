//! Operator-generality integration tests: `Conv2d` (strided / padded),
//! `GroupedConv2d` (depthwise), `BatchedGemm` and the `FusedAttention`
//! chain compile through the SAME candgen → compile → select pipeline
//! as GEMM (no operator-specific side path) and execute in the
//! simulator; attention additionally serves through the BatchedGemm
//! measurement-alias fixpoint when no native library is loaded.

use vortex::compiler::{compile, CompileOpts, MicroKernelLibrary};
use vortex::coordinator::{HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{DType, OpKind, TensorProgram};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;
use vortex::util::json::Json;

fn compile_lib(op: OpKind) -> MicroKernelLibrary {
    let hw = presets::a100();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 7));
    let r = compile(&hw, op, DType::F16, &cfg, &mut prof, &CompileOpts::default());
    assert!(!r.library.kernels.is_empty(), "{} library is empty", op);
    assert!(r.profile_queries > 0, "{} compiled without profiling", op);
    r.library
}

#[test]
fn conv2d_end_to_end_through_native_library() {
    let hw = presets::a100();
    let lib = compile_lib(OpKind::Conv2d);
    let selector = Selector::new(hw.clone(), vec![lib]);
    assert!(selector.has_op(OpKind::Conv2d));

    // ResNet-ish strided+padded conv with a dynamic batch: select +
    // construct + simulate, through the generalized geometry.
    let sim = Simulator::new(hw, 7);
    for batch in [1usize, 3, 17] {
        let p = TensorProgram::conv2d(
            (batch, 28, 28, 128),
            (3, 3, 256),
            (2, 1, 1),
            DType::F16,
        )
        .expect("valid geometry");
        assert_eq!(p.conv_output(), Some((14, 14)));
        let space = p.space();
        let sel = selector.select(space, HwMode::Adaptive).expect("conv select");
        let kern = selector.kernel(&sel);
        for d in 0..3 {
            assert!(sel.padded[d] >= space.dims[d]);
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
        assert!(sel.est_secs > 0.0);
    }
}

#[test]
fn batched_gemm_end_to_end_through_native_library() {
    let hw = presets::a100();
    let lib = compile_lib(OpKind::BatchedGemm);
    assert!(lib.kernels.iter().all(|k| k.l1.rank() == 4));
    let selector = Selector::new(hw.clone(), vec![lib]);
    let sim = Simulator::new(hw, 7);

    // Attention-shaped batched GEMMs with dynamic batch x seq.
    for (b, s, hd) in [(12usize, 77usize, 64usize), (1, 476, 128), (96, 9, 32)] {
        let p = TensorProgram::BatchedGemm { b, m: s, n: s, k: hd, dtype: DType::F16 };
        let space = p.space();
        let sel = selector.select(space, HwMode::Adaptive).expect("bgemm select");
        let kern = selector.kernel(&sel);
        assert_eq!(sel.padded.rank(), 4);
        for d in 0..4 {
            assert!(sel.padded[d] >= space.dims[d]);
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
    }
}

#[test]
fn batched_selection_scales_with_batch() {
    // More batches = more work: the selection estimate must grow, and a
    // batch-B problem must never be estimated cheaper than batch-1.
    let hw = presets::a100();
    let selector = Selector::new(hw, vec![compile_lib(OpKind::BatchedGemm)]);
    let est = |b: usize| {
        let p = TensorProgram::BatchedGemm { b, m: 128, n: 128, k: 64, dtype: DType::F16 };
        selector.select(p.space(), HwMode::Adaptive).unwrap().est_secs
    };
    let (e1, e16, e128) = (est(1), est(16), est(128));
    assert!(e16 > e1, "{} !> {}", e16, e1);
    assert!(e128 > e16, "{} !> {}", e128, e16);
}

#[test]
fn grouped_conv2d_end_to_end_through_native_library() {
    let hw = presets::a100();
    let lib = compile_lib(OpKind::GroupedConv2d);
    assert!(lib.kernels.iter().all(|k| k.l1.rank() == 4));
    let selector = Selector::new(hw.clone(), vec![lib]);
    assert!(selector.has_op(OpKind::GroupedConv2d));
    let sim = Simulator::new(hw, 7);

    // MobileNet-style depthwise (groups == cin) and ResNeXt-style
    // grouped convs with dynamic batch.
    for (batch, hw_, c, stride, groups) in
        [(1usize, 28usize, 128usize, 1usize, 128usize), (9, 14, 256, 2, 256), (4, 28, 128, 1, 32)]
    {
        let p = TensorProgram::conv2d(
            (batch, hw_, hw_, c),
            (3, 3, c),
            (stride, 1, groups),
            DType::F16,
        )
        .expect("valid geometry");
        let space = p.space();
        assert_eq!(space.op, OpKind::GroupedConv2d);
        assert_eq!(space.dims[0], groups);
        let sel = selector.select(space, HwMode::Adaptive).expect("grouped select");
        let kern = selector.kernel(&sel);
        assert_eq!(sel.padded.rank(), 4);
        for d in 0..4 {
            assert!(sel.padded[d] >= space.dims[d]);
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
    }
}

#[test]
fn invalid_conv_geometry_errors_before_the_pipeline() {
    // Program layer: construction is the error surface.
    assert!(
        TensorProgram::conv2d((2, 2, 2, 4), (3, 3, 8), (1, 0, 1), DType::F16).is_err()
    );
    assert!(
        TensorProgram::conv2d((1, 8, 8, 4), (3, 3, 8), (0, 0, 1), DType::F16).is_err()
    );
    assert!(
        TensorProgram::conv2d((1, 8, 8, 7), (3, 3, 8), (1, 0, 2), DType::F16).is_err()
    );
}

#[test]
#[should_panic(expected = "invalid tensor program")]
fn invalid_conv_space_never_reaches_the_selector() {
    // `space()` is the only door into candgen / cost / selection; a
    // literally-constructed invalid program panics there instead of
    // producing the old silently-wrong oh = ow = 1 space.
    let p = TensorProgram::Conv2d {
        n: 2,
        h: 2,
        w: 2,
        cin: 4,
        cout: 8,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 0,
        groups: 1,
        dtype: DType::F16,
    };
    let _ = p.space();
}

#[test]
fn per_op_libraries_round_trip_through_disk_with_op_field() {
    for op in [
        OpKind::Conv2d,
        OpKind::BatchedGemm,
        OpKind::GroupedConv2d,
        OpKind::FusedAttention,
    ] {
        let lib = compile_lib(op);
        let text = lib.to_json().dump();
        assert!(text.contains(&format!("\"op\":\"{}\"", op.name())));
        let lib2 =
            MicroKernelLibrary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(lib2.op, op);
        assert_eq!(lib2.kernels, lib.kernels);
    }
}

#[test]
fn conv_suite_serves_through_gemm_fallback_and_native_equally() {
    // The conv strategy space IS the contraction space, so serving a
    // conv through its native library or through the GEMM library must
    // construct the same kernel chain.
    let hw = presets::a100();
    let conv_sel = Selector::new(hw.clone(), vec![compile_lib(OpKind::Conv2d)]);
    let gemm_sel = Selector::new(hw, vec![compile_lib(OpKind::Gemm)]);
    // Same-padded 3x3 — the padded geometry flows through both paths.
    let p = TensorProgram::conv2d((4, 14, 14, 512), (3, 3, 512), (1, 1, 1), DType::F16)
        .expect("valid geometry");
    assert_eq!(p.conv_output(), Some((14, 14)));
    let a = conv_sel.select(p.space(), HwMode::Adaptive).unwrap();
    let b = gemm_sel.select(p.space(), HwMode::Adaptive).unwrap();
    assert_eq!(conv_sel.kernel(&a).l1, gemm_sel.kernel(&b).l1);
    assert_eq!(a.padded, b.padded);
}

#[test]
fn attention_suite_serves_end_to_end_through_batched_gemm_alias_fixpoint() {
    // Acceptance: the whole attention suite compiles and executes
    // through the selector with NO attention-specific side path — the
    // only library loaded is a BatchedGemm one, and every chain serves
    // via the measurement-alias fixpoint FusedAttention → BatchedGemm.
    let hw = presets::a100();
    let lib = compile_lib(OpKind::BatchedGemm);
    let selector = Selector::new(hw.clone(), vec![lib]);
    assert!(!selector.has_op(OpKind::FusedAttention));
    let sim = Simulator::new(hw, 7);
    let cases = vortex::bench::workloads::attention_suite(DType::F16, 7);
    assert!(!cases.is_empty());
    for case in &cases {
        let space = case.program.space();
        assert_eq!(space.op, OpKind::FusedAttention);
        let sel = selector
            .select(space, HwMode::Adaptive)
            .unwrap_or_else(|| panic!("no kernel for {}", case.program.id()));
        let kern = selector.kernel(&sel);
        assert_eq!(sel.padded.rank(), 4);
        for d in 0..4 {
            assert!(sel.padded[d] >= space.dims[d], "{}", case.program.id());
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        // The constructed chain executes in the simulator (the alias
        // block strategy, one per constituent kernel).
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0, "{}", case.program.id());
        assert!(sel.est_secs > 0.0);
    }
}

#[test]
fn attention_native_library_compiles_end_to_end() {
    // The fused chain also compiles a NATIVE library through the same
    // pipeline: candgen over the shared ladders (pruned by the fused
    // working set), alias-decomposed ranking, and the softmax
    // micro-measurement folded into each kernel's base_cost.
    let hw = presets::a100();
    let lib = compile_lib(OpKind::FusedAttention);
    assert!(lib.kernels.iter().all(|k| k.l1.rank() == 4));
    let selector = Selector::new(hw.clone(), vec![lib]);
    assert!(selector.has_op(OpKind::FusedAttention));
    let sim = Simulator::new(hw, 7);
    for (batch, seq, d, heads) in
        [(1usize, 476usize, 768usize, 12usize), (2, 77, 1024, 16), (8, 1, 512, 8)]
    {
        let p = TensorProgram::attention((batch, seq), (d, heads), DType::F16)
            .expect("valid geometry");
        let space = p.space();
        let sel = selector.select(space, HwMode::Adaptive).expect("attn select");
        let kern = selector.kernel(&sel);
        for dim in 0..4 {
            assert!(sel.padded[dim] >= space.dims[dim]);
            assert_eq!(sel.padded[dim] % kern.l1[dim], 0);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
    }
}

#[test]
fn invalid_attention_geometry_errors_before_the_pipeline() {
    // Program layer: construction is the error surface (mirrors conv).
    assert!(TensorProgram::attention((1, 64), (768, 7), DType::F16).is_err());
    assert!(TensorProgram::attention((0, 64), (768, 12), DType::F16).is_err());
    assert!(TensorProgram::attention((1, 64), (768, 0), DType::F16).is_err());
}

#[test]
#[should_panic(expected = "invalid tensor program")]
fn invalid_attention_space_never_reaches_the_selector() {
    let p = TensorProgram::Attention { batch: 1, seq: 64, d: 768, heads: 5, dtype: DType::F16 };
    let _ = p.space();
}

#[test]
fn depthwise_conv_serves_through_batched_gemm_fallback_and_native_equally() {
    // The grouped strategy space IS the per-group batched contraction
    // space: native grouped library and BatchedGemm fallback must
    // construct the same kernel chain for a depthwise program.
    let hw = presets::a100();
    let grouped_sel = Selector::new(hw.clone(), vec![compile_lib(OpKind::GroupedConv2d)]);
    let bgemm_sel = Selector::new(hw, vec![compile_lib(OpKind::BatchedGemm)]);
    let p = TensorProgram::conv2d((2, 56, 56, 64), (3, 3, 64), (1, 1, 64), DType::F16)
        .expect("valid geometry");
    let a = grouped_sel.select(p.space(), HwMode::Adaptive).unwrap();
    let b = bgemm_sel.select(p.space(), HwMode::Adaptive).unwrap();
    assert_eq!(grouped_sel.kernel(&a).l1, bgemm_sel.kernel(&b).l1);
    assert_eq!(a.padded, b.padded);
}
