//! Operator-generality integration tests: `Conv2d` and `BatchedGemm`
//! compile through the SAME candgen → compile → select pipeline as
//! GEMM (no operator-specific side path) and execute in the simulator.

use vortex::compiler::{compile, CompileOpts, MicroKernelLibrary};
use vortex::coordinator::{HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{DType, OpKind, TensorProgram};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;
use vortex::util::json::Json;

fn compile_lib(op: OpKind) -> MicroKernelLibrary {
    let hw = presets::a100();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 7));
    let r = compile(&hw, op, DType::F16, &cfg, &mut prof, &CompileOpts::default());
    assert!(!r.library.kernels.is_empty(), "{} library is empty", op);
    assert!(r.profile_queries > 0, "{} compiled without profiling", op);
    r.library
}

#[test]
fn conv2d_end_to_end_through_native_library() {
    let hw = presets::a100();
    let lib = compile_lib(OpKind::Conv2d);
    let selector = Selector::new(hw.clone(), vec![lib]);
    assert!(selector.has_op(OpKind::Conv2d));

    // ResNet-ish conv with a dynamic batch: select + construct + simulate.
    let sim = Simulator::new(hw, 7);
    for batch in [1usize, 3, 17] {
        let p = TensorProgram::Conv2d {
            n: batch,
            h: 28,
            w: 28,
            cin: 128,
            cout: 256,
            kh: 3,
            kw: 3,
            dtype: DType::F16,
        };
        let space = p.space();
        let sel = selector.select(space, HwMode::Adaptive).expect("conv select");
        let kern = selector.kernel(&sel);
        for d in 0..3 {
            assert!(sel.padded[d] >= space.dims[d]);
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
        assert!(sel.est_secs > 0.0);
    }
}

#[test]
fn batched_gemm_end_to_end_through_native_library() {
    let hw = presets::a100();
    let lib = compile_lib(OpKind::BatchedGemm);
    assert!(lib.kernels.iter().all(|k| k.l1.rank() == 4));
    let selector = Selector::new(hw.clone(), vec![lib]);
    let sim = Simulator::new(hw, 7);

    // Attention-shaped batched GEMMs with dynamic batch x seq.
    for (b, s, hd) in [(12usize, 77usize, 64usize), (1, 476, 128), (96, 9, 32)] {
        let p = TensorProgram::BatchedGemm { b, m: s, n: s, k: hd, dtype: DType::F16 };
        let space = p.space();
        let sel = selector.select(space, HwMode::Adaptive).expect("bgemm select");
        let kern = selector.kernel(&sel);
        assert_eq!(sel.padded.rank(), 4);
        for d in 0..4 {
            assert!(sel.padded[d] >= space.dims[d]);
            assert_eq!(sel.padded[d] % kern.l1[d], 0);
            assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
        }
        let secs = sim.execute(DType::F16, &selector.chain(&sel));
        assert!(secs.is_finite() && secs > 0.0);
    }
}

#[test]
fn batched_selection_scales_with_batch() {
    // More batches = more work: the selection estimate must grow, and a
    // batch-B problem must never be estimated cheaper than batch-1.
    let hw = presets::a100();
    let selector = Selector::new(hw, vec![compile_lib(OpKind::BatchedGemm)]);
    let est = |b: usize| {
        let p = TensorProgram::BatchedGemm { b, m: 128, n: 128, k: 64, dtype: DType::F16 };
        selector.select(p.space(), HwMode::Adaptive).unwrap().est_secs
    };
    let (e1, e16, e128) = (est(1), est(16), est(128));
    assert!(e16 > e1, "{} !> {}", e16, e1);
    assert!(e128 > e16, "{} !> {}", e128, e16);
}

#[test]
fn per_op_libraries_round_trip_through_disk_with_op_field() {
    for op in [OpKind::Conv2d, OpKind::BatchedGemm] {
        let lib = compile_lib(op);
        let text = lib.to_json().dump();
        assert!(text.contains(&format!("\"op\":\"{}\"", op.name())));
        let lib2 =
            MicroKernelLibrary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(lib2.op, op);
        assert_eq!(lib2.kernels, lib.kernels);
    }
}

#[test]
fn conv_suite_serves_through_gemm_fallback_and_native_equally() {
    // The conv strategy space IS the contraction space, so serving a
    // conv through its native library or through the GEMM library must
    // construct the same kernel chain.
    let hw = presets::a100();
    let conv_sel = Selector::new(hw.clone(), vec![compile_lib(OpKind::Conv2d)]);
    let gemm_sel = Selector::new(hw, vec![compile_lib(OpKind::Gemm)]);
    let p = TensorProgram::Conv2d {
        n: 4,
        h: 14,
        w: 14,
        cin: 512,
        cout: 512,
        kh: 3,
        kw: 3,
        dtype: DType::F16,
    };
    let a = conv_sel.select(p.space(), HwMode::Adaptive).unwrap();
    let b = gemm_sel.select(p.space(), HwMode::Adaptive).unwrap();
    assert_eq!(conv_sel.kernel(&a).l1, gemm_sel.kernel(&b).l1);
    assert_eq!(a.padded, b.padded);
}
