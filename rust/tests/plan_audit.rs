//! Integration gate for the symbolic plan auditor: every shipped
//! preset × op × dtype grid must audit clean — write-set disjointness,
//! capacity bounds, alias fixpoints and (when built) dispatch-table
//! region soundness are proved over whole axis intervals, so a clean
//! report here is a proof over every in-horizon shape, not a sample.
//!
//! The seeded-corruption counterparts (tampered edges, swapped
//! winners, undersized capacities, overlapping mock write-sets) live
//! in `rust/src/analysis/tests.rs` where `pub(crate)` access allows
//! in-place tampering.

use vortex::analysis::{audit, audit_dispatch_table, AuditConfig};
use vortex::compiler::{compile, CompileOpts, MicroKernelLibrary};
use vortex::coordinator::Selector;
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::dispatch::{DispatchConfig, DispatchTable};
use vortex::hw::presets;
use vortex::hw::HwSpec;
use vortex::ir::{DType, OpKind};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;

/// The shipped grid: each preset with the dtypes its backends serve.
fn grid() -> Vec<(HwSpec, Vec<DType>)> {
    vec![
        (presets::a100(), vec![DType::F32, DType::F16]),
        (presets::xeon_8255c(), vec![DType::F32]),
        (presets::cpu_pjrt(), vec![DType::F32, DType::Bf16]),
    ]
}

/// Compile every op of `OpKind::ALL` for each dtype into one selector
/// (analytical analyzer: the audit proves plan invariants, not cost
/// accuracy, and CI runs this in debug mode).
fn full_selector(hw: &HwSpec, dtypes: &[DType]) -> Selector {
    let cfg = AnalyzerConfig::analytical_only();
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 7));
    let mut libs: Vec<MicroKernelLibrary> = Vec::new();
    for &dtype in dtypes {
        for op in OpKind::ALL {
            libs.push(compile(hw, op, dtype, &cfg, &mut prof, &CompileOpts::default()).library);
        }
    }
    Selector::new(hw.clone(), libs)
}

fn small_dispatch_config() -> DispatchConfig {
    DispatchConfig {
        horizon: 48,
        batch_horizon: 6,
        max_cells: 1 << 14,
        ..DispatchConfig::default()
    }
}

#[test]
fn every_preset_op_dtype_grid_audits_clean() {
    for (hw, dtypes) in grid() {
        let selector = full_selector(&hw, &dtypes);
        let report = audit(&selector, &AuditConfig::default());
        assert!(
            report.diagnostics.is_empty(),
            "{}: expected a clean audit, got:\n{}",
            hw.name,
            report
                .diagnostics
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(report.kernels_checked > 0, "{}: audit was vacuous", hw.name);
        assert!(report.segments_checked > 0, "{}: no write-set segments", hw.name);
    }
}

#[test]
fn dispatch_tables_audit_clean_on_every_preset() {
    let dcfg = small_dispatch_config();
    for (hw, dtypes) in grid() {
        let selector = full_selector(&hw, &dtypes);
        let table = DispatchTable::for_selector(&selector, &dcfg);
        let report = audit_dispatch_table(&selector, &table);
        assert!(
            report.diagnostics.is_empty(),
            "{}: dispatch audit found:\n{}",
            hw.name,
            report
                .diagnostics
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert_eq!(report.tables_checked, table.stats.tables, "{}", hw.name);
        assert!(report.cells_checked > 0, "{}: no cells re-proved", hw.name);
    }
}

#[test]
fn serialized_tables_survive_the_strict_loader_and_re_audit_clean() {
    let (hw, dtypes) = (presets::a100(), vec![DType::F32, DType::F16]);
    let selector = full_selector(&hw, &dtypes);
    let table = DispatchTable::for_selector(&selector, &small_dispatch_config());
    let payload = table.to_data(&selector);
    let adopted = DispatchTable::from_data_checked(&selector, &payload)
        .expect("round-tripped payload must load");
    let report = audit_dispatch_table(&selector, &adopted);
    assert!(
        report.diagnostics.is_empty(),
        "round-tripped table audit found:\n{}",
        report
            .diagnostics
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
