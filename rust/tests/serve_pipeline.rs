//! Serving-subsystem integration tests: the multi-op request lanes +
//! bucketed plan cache end to end, including the acceptance gate —
//! a mixed trace (>= 3 op kinds, >= 200 requests) must reach >= 90%
//! plan-cache hit rate after warmup with strictly lower scheduling
//! seconds than the cache-disabled run and IDENTICAL per-request
//! selections.

use std::collections::HashSet;

use vortex::coordinator::Selector;
use vortex::hw::presets;
use vortex::ir::{DType, OpKind, TensorProgram};
use vortex::serve::{
    scenario, serve_mixed_trace, LaneClass, MixedStats, ServeConfig, ServeRequest,
    SimLaneEngine,
};
use vortex::sim::Simulator;

fn selector() -> Selector {
    scenario::demo_selector(7)
}

fn engine() -> SimLaneEngine {
    SimLaneEngine { sim: Simulator::new(presets::a100(), 7) }
}

fn run(selector: &Selector, cfg: &ServeConfig, trace: &[ServeRequest]) -> MixedStats {
    serve_mixed_trace(&mut engine(), selector, cfg, trace)
}

/// Everything deterministic about an outcome (latency and select_secs
/// carry wall-clock and are excluded).
fn shape_of(stats: &MixedStats) -> Vec<(u64, LaneClass, usize, usize, usize, String, String)> {
    stats
        .outcomes
        .iter()
        .map(|o| {
            (
                o.id,
                o.lane,
                o.batch_size,
                o.selection.lib,
                o.selection.kernel,
                format!("{:?}", o.selection.padded),
                format!("{:?}", o.selection.grid),
            )
        })
        .collect()
}

#[test]
fn acceptance_mixed_trace_cache_hit_rate_and_identity() {
    let s = selector();
    let trace = scenario::mixed_trace(600, 4e-4, 9, DType::F32);
    assert!(trace.len() >= 200, "acceptance gate requires >= 200 requests");
    let kinds: HashSet<OpKind> = trace.iter().map(|r| r.program.space().op).collect();
    assert!(kinds.len() >= 3, "acceptance gate requires >= 3 op kinds, got {:?}", kinds);

    let cfg = scenario::serving_config();
    let cached = run(&s, &cfg, &trace);
    let baseline = run(&s, &cfg.without_cache(), &trace);

    // Every request served exactly once, in both runs.
    for stats in [&cached, &baseline] {
        let ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, (0..trace.len() as u64).collect::<Vec<_>>());
    }

    // Identical per-request selections: the plan cache must be
    // invisible to WHAT is executed (plan identity is
    // `Selection::same_plan`; shape_of additionally pins lane/batch).
    assert_eq!(shape_of(&cached), shape_of(&baseline));
    for (a, b) in cached.outcomes.iter().zip(&baseline.outcomes) {
        assert!(a.selection.same_plan(&b.selection), "plan diverged for request {}", a.id);
    }

    // Cache effectiveness: >= 90% hit rate after warmup (second half of
    // the request stream), strictly lower total scheduling seconds.
    assert!(cached.cache.hits > 0 && cached.cache.misses > 0);
    assert_eq!(baseline.cache.lookups(), 0);
    let warm = vortex::bench::exp_serve::warm_hit_rate(&cached);
    assert!(
        warm >= 0.9,
        "warm hit rate {:.3} < 0.9 ({} hits / {} misses overall)",
        warm,
        cached.cache.hits,
        cached.cache.misses
    );
    // Deterministic form of the same criterion first: the cached run
    // executes a full selection scan ONLY on misses — strictly fewer
    // scans than the baseline's one per batch (batching is identical
    // in both runs, so baseline lookups == cached lookups).
    let baseline_batches: usize = baseline.lanes.iter().map(|l| l.batches).sum();
    assert!(
        (cached.cache.misses as usize) < baseline_batches,
        "cache saved no selection scans: {} misses / {} batches",
        cached.cache.misses,
        baseline_batches
    );
    assert!(
        cached.total_sched_secs() < baseline.total_sched_secs(),
        "cached scheduling {} !< baseline {}",
        cached.total_sched_secs(),
        baseline.total_sched_secs()
    );
}

#[test]
fn acceptance_dispatch_table_zero_warmup_and_identity() {
    // The offline shape-space partition serves the SAME mixed trace as
    // the acceptance gate with compile-time dispatch: identical
    // per-request plans, tri-state accounting that covers every
    // request, and — whenever the configured envelope fit the cell
    // budget — zero cold misses (100% warm start from request 1),
    // versus the reactive cache's one fresh scan per bucket.
    let s = selector();
    let trace = scenario::mixed_trace(600, 4e-4, 9, DType::F32);
    let cfg = scenario::serving_config();
    let dispatch_cfg = cfg.with_dispatch(scenario::dispatch_config());

    let table = run(&s, &dispatch_cfg, &trace);
    let cached = run(&s, &cfg, &trace);
    let baseline = run(&s, &cfg.without_cache(), &trace);

    // The table must be invisible to WHAT executes.
    assert_eq!(shape_of(&table), shape_of(&baseline));
    for (a, b) in table.outcomes.iter().zip(&baseline.outcomes) {
        assert!(
            a.selection.same_plan(&b.selection),
            "plan diverged for request {} (source {:?})",
            a.id,
            a.source
        );
    }

    // Tri-state accounting sums to the request count.
    assert_eq!(table.dispatch.total() as usize, trace.len());
    assert!(table.dispatch.table > 0, "dispatch table answered nothing");

    let build = table.dispatch_build.as_ref().expect("dispatch was enabled");
    if !build.clamped {
        // Full envelope coverage: no fresh scans anywhere — the
        // warm-start property the reactive cache cannot have.
        assert_eq!(
            table.dispatch.fresh, 0,
            "cold miss despite unclamped table coverage"
        );
        assert_eq!(table.dispatch.warm_start_rate(), 1.0);
        assert_eq!(table.dispatch.cache, 0);
    }
    // Deterministic scheduling-work comparison (wall-clock-free):
    // batching is identical in every run, and the table run's plan
    // cache only ever sees the beyond-horizon tail — it can never run
    // more full selection scans (cache misses) than the cache-only
    // baseline, and with full coverage it runs none.
    assert!(
        table.cache.misses <= cached.cache.misses,
        "table run scanned more than the cache baseline: {} vs {}",
        table.cache.misses,
        cached.cache.misses
    );
    // Region merging actually compressed the enumerated lattice.
    assert!(build.cells <= build.cells_enumerated);
    assert!(build.tables >= 3, "expected tables for >= 3 op kinds");
}

#[test]
fn lane_batching_invariants_hold_per_lane() {
    let s = selector();
    let trace = scenario::mixed_trace(240, 2e-4, 11, DType::F32);
    // Distinct per-lane caps: each lane must respect ITS OWN config.
    let mut cfg = scenario::serving_config();
    cfg.lane_mut(LaneClass::Gemm).max_batch = 3;
    cfg.lane_mut(LaneClass::Conv).max_batch = 2;
    cfg.lane_mut(LaneClass::Attention).max_batch = 5;
    let stats = run(&s, &cfg, &trace);

    // No request lost or duplicated.
    let ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
    assert_eq!(ids, (0..trace.len() as u64).collect::<Vec<_>>());

    // Per-lane max_batch respected; batches merge only key-compatible
    // programs, so batch sizes never exceed the lane's own cap.
    for o in &stats.outcomes {
        let cap = cfg.lane(o.lane).max_batch;
        assert!(
            o.batch_size <= cap,
            "lane {} batch {} > cap {}",
            o.lane.name(),
            o.batch_size,
            cap
        );
        assert!(o.latency >= 0.0);
    }
    // The trace exercises at least three lanes.
    let lanes: HashSet<LaneClass> = stats.outcomes.iter().map(|o| o.lane).collect();
    assert!(lanes.len() >= 3, "{:?}", lanes);
}

#[test]
fn mixed_trace_replay_is_deterministic() {
    let s = selector();
    let trace = scenario::mixed_trace(200, 4e-4, 5, DType::F32);
    let cfg = scenario::serving_config();
    let a = run(&s, &cfg, &trace);
    let b = run(&s, &cfg, &trace);
    // The event clock charges a MODELED scheduling overhead (never
    // this machine's wall-clock), so the full replay — who batched
    // with whom, which plan executed, which lookups hit, every
    // latency — is bit-identical.
    assert_eq!(shape_of(&a), shape_of(&b));
    let lats = |s: &MixedStats| s.outcomes.iter().map(|o| o.latency).collect::<Vec<_>>();
    assert_eq!(lats(&a), lats(&b));
    assert_eq!(a.span_secs, b.span_secs);
    let hits = |s: &MixedStats| s.outcomes.iter().map(|o| o.source).collect::<Vec<_>>();
    assert_eq!(hits(&a), hits(&b));
    assert_eq!(a.cache.hits, b.cache.hits);
    assert_eq!(a.cache.misses, b.cache.misses);
    let per_lane = |s: &MixedStats| {
        s.lanes.iter().map(|l| (l.class, l.batches, l.total_units)).collect::<Vec<_>>()
    };
    assert_eq!(per_lane(&a), per_lane(&b));
}

#[test]
fn legacy_gemm_api_matches_one_lane_serving() {
    // The old GEMM-only serve_trace delegates to a one-lane instance:
    // a pure-GEMM trace through serve_mixed_trace must produce the
    // same batching structure.
    use vortex::coordinator::server::{gen_trace, serve_trace, ServerConfig, SimEngine};
    let s = selector();
    let legacy_trace = gen_trace(50, 5e-4, 1, 128, 3);
    let cfg = ServerConfig::default();
    let mut legacy_engine = SimEngine { sim: Simulator::new(presets::a100(), 7) };
    let legacy = serve_trace(&mut legacy_engine, &s, &cfg, &legacy_trace);

    let requests: Vec<ServeRequest> = legacy_trace
        .iter()
        .map(|r| ServeRequest {
            id: r.id,
            program: TensorProgram::Gemm { m: r.rows, n: cfg.n, k: cfg.k, dtype: cfg.dtype },
            arrive: r.arrive,
            steps: 1,
        })
        .collect();
    let serve_cfg = ServeConfig { plan_cache: None, ..ServeConfig::default() };
    let mixed = run(&s, &serve_cfg, &requests);

    assert_eq!(legacy.metrics.count(), mixed.count());
    assert_eq!(legacy.batches, mixed.lanes[0].batches);
    assert_eq!(legacy.total_rows, mixed.lanes[0].total_units);
    let legacy_sizes: Vec<(u64, usize)> =
        legacy.outcomes.iter().map(|o| (o.id, o.batch_size)).collect();
    let mixed_sizes: Vec<(u64, usize)> =
        mixed.outcomes.iter().map(|o| (o.id, o.batch_size)).collect();
    assert_eq!(legacy_sizes, mixed_sizes);
    // Selection through the mixed path serves every request with a
    // native GEMM-library plan (lib 0 here, the only gemm library).
    assert!(mixed.outcomes.iter().all(|o| o.lane == LaneClass::Gemm));
}

#[test]
fn heavier_load_fills_batches_and_cache_stays_exact() {
    // Under heavy load (tiny gaps) batches fill toward the caps and
    // merged shapes get bigger — the cached plans must STILL match
    // fresh selection exactly (the bucket key is sound, not heuristic).
    let s = selector();
    let trace = scenario::mixed_trace(300, 2e-5, 13, DType::F32);
    let cfg = scenario::serving_config();
    let cached = run(&s, &cfg, &trace);
    let fresh = run(&s, &cfg.without_cache(), &trace);
    assert_eq!(shape_of(&cached), shape_of(&fresh));
    assert!(cached.outcomes.iter().any(|o| o.batch_size > 1), "load never batched");
    // Selection-time telemetry: a hit's select_secs is the lookup, not
    // the scan — the mean scheduling share must not exceed baseline.
    assert!(cached.total_sched_secs() <= fresh.total_sched_secs());
}
