"""Fused epilogue + softmax Pallas kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gemm_epilogue, ref, softmax_tile


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32).astype(
        dtype
    )


@pytest.mark.parametrize("act", ["gelu", "relu", "none"])
def test_gemm_bias_act_matches_ref(act):
    m, n, k = 64, 256, 256
    a, b, bias = _rand((m, k), 0), _rand((k, n), 1), _rand((n,), 2)
    got = gemm_epilogue.gemm_bias_act(a, b, bias, tm=32, tn=128, tk=128, act=act)
    want = ref.gemm_bias_act_ref(a, b, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_bias_act_multi_k_step():
    """Epilogue must fire only on the LAST K step (store-stage fusion)."""
    m, n, k = 32, 128, 512  # 4 K steps of 128
    a, b, bias = _rand((m, k), 3), _rand((k, n), 4), _rand((n,), 5)
    got = gemm_epilogue.gemm_bias_act(a, b, bias, tm=32, tn=128, tk=128, act="gelu")
    want = ref.gemm_bias_act_ref(a, b, bias, act="gelu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_bias_act_rejects_bad_act():
    a, b, bias = _rand((8, 128), 0), _rand((128, 128), 1), _rand((128,), 2)
    with pytest.raises(ValueError, match="unknown act"):
        gemm_epilogue.gemm_bias_act(a, b, bias, tm=8, tn=128, tk=128, act="swish")


@pytest.mark.parametrize("r,c,tr", [(8, 16, 8), (128, 128, 8), (64, 256, 16)])
def test_softmax_matches_ref(r, c, tr):
    x = _rand((r, c), 6) * 4.0
    got = softmax_tile.softmax(x, tr=tr)
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    x = _rand((32, 64), 7) * 10.0
    got = softmax_tile.softmax(x, tr=8)
    np.testing.assert_allclose(jnp.sum(got, axis=-1), jnp.ones(32), rtol=1e-5)


def test_softmax_stable_at_large_logits():
    x = jnp.full((8, 16), 1e4, jnp.float32)
    got = softmax_tile.softmax(x, tr=8)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_allclose(got, jnp.full((8, 16), 1.0 / 16.0), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    ri=st.integers(1, 8),
    c=st.sampled_from([16, 64, 128, 256]),
    scale=st.floats(0.1, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_hypothesis(ri, c, scale, seed):
    x = _rand((ri * 8, c), seed) * scale
    got = softmax_tile.softmax(x, tr=8)
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-4, atol=1e-6)
