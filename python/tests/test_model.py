"""L2 model graphs: conv-as-implicit-GEMM and encoder layer vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32).astype(
        dtype
    )


def test_im2col_matches_direct_conv():
    x = _rand((2, 10, 10, 4), 0)
    w = _rand((3, 3, 4, 8), 1)
    patches = ref.im2col_ref(x, 3, 3)
    wmat = w.reshape(3 * 3 * 4, 8)
    out = (patches @ wmat).reshape(2, 8, 8, 8)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


def test_conv2d_im2col_pallas_matches_ref():
    x = _rand((1, 18, 18, 64), 2)
    w = _rand((3, 3, 64, 128), 3)
    got = model.conv2d_im2col(x, w, tm=8, tn=128, tk=576)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w), rtol=1e-3, atol=1e-3)


def test_conv2d_im2col_stride1_small():
    x = _rand((2, 6, 6, 8), 4)
    w = _rand((3, 3, 8, 16), 5)
    # rows = 2*4*4 = 32, K = 72, N = 16 — tiny tiles exercise odd shapes
    got = model.conv2d_im2col(x, w, tm=8, tn=16, tk=72)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seq", [16, 64])
def test_encoder_layer_matches_ref(seq):
    d, ff, heads = 256, 1024, 4
    x = _rand((seq, d), 10)
    # fan-in-scaled inits (as real networks use) keep intermediates O(1);
    # unscaled weights amplify accumulation-order noise via cancellation.
    params = tuple(
        _rand(s.shape, 11 + i) / (s.shape[0] ** 0.5)
        for i, s in enumerate(model.encoder_params_spec(d, ff))
    )
    got = model.encoder_layer(x, params, n_heads=heads, tm=8, tn=128, tk=128)
    want = ref.encoder_layer_ref(x, *params, n_heads=heads)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_encoder_layer_shapes_all_buckets():
    """Every AOT bucket must trace: shape errors surface here, not in aot."""
    d, ff, heads = 256, 1024, 4
    for seq in (64, 128, 256):
        fn, args = model.make_encoder_layer(seq, d, ff, heads, tm=8, tn=128, tk=128)
        out = jax.eval_shape(fn, *args)
        assert out[0].shape == (seq, d)


def test_builders_registry_covers_manifest_kinds():
    import json
    import os

    path = os.path.join(os.path.dirname(model.__file__), "microkernels.json")
    with open(path) as f:
        spec = json.load(f)
    kinds = {e["kind"] for e in spec["entries"]}
    assert kinds <= set(model.BUILDERS), kinds - set(model.BUILDERS)


def test_manifest_entries_trace():
    """jax.eval_shape every manifest entry — cheap full-manifest guard."""
    import json
    import os

    path = os.path.join(os.path.dirname(model.__file__), "microkernels.json")
    with open(path) as f:
        spec = json.load(f)
    for entry in spec["entries"]:
        fn, args = model.BUILDERS[entry["kind"]](**entry["params"])
        out = jax.eval_shape(fn, *args)
        assert len(out) == 1, entry["name"]
