"""AOT bridge: lowering produces loadable HLO text + faithful IO specs."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_entry_produces_hlo_text_and_io_spec():
    entry = {
        "name": "t_gemm_acc",
        "kind": "gemm_acc",
        "params": {
            "bm": 8, "bn": 128, "bk": 128,
            "tm": 8, "tn": 128, "tk": 128,
            "in_dtype": "f32",
        },
    }
    text, annotated = aot.lower_entry(entry)
    # HLO text module with an entry computation and a dot.
    assert text.startswith("HloModule")
    assert "dot(" in text or "dot " in text
    # IO spec matches the builder contract.
    assert annotated["inputs"][0]["shape"] == [8, 128]
    assert annotated["inputs"][1]["shape"] == [128, 128]
    assert annotated["inputs"][2]["shape"] == [8, 128]
    assert annotated["outputs"][0]["shape"] == [8, 128]
    assert annotated["file"] == "t_gemm_acc.hlo.txt"
    assert len(annotated["sha256"]) == 16


def test_lowered_outputs_are_untupled():
    # EXPERIMENTS.md §Perf L2: the rust constructor chains the raw output
    # buffer back in; a tuple root would force a host round trip.
    entry = {
        "name": "t_small",
        "kind": "gemm",
        "params": {
            "bm": 8, "bn": 128, "bk": 128,
            "tm": 8, "tn": 128, "tk": 128,
            "in_dtype": "f32",
        },
    }
    text, _ = aot.lower_entry(entry)
    root = [l for l in text.splitlines() if "ROOT" in l]
    assert root, "no ROOT instruction"
    assert "tuple(" not in root[-1], f"tupled root: {root[-1]}"


def test_checked_in_manifest_is_consistent_with_builders():
    path = os.path.join(os.path.dirname(model.__file__), "microkernels.json")
    with open(path) as f:
        spec = json.load(f)
    for entry in spec["entries"]:
        fn, args = model.BUILDERS[entry["kind"]](**entry["params"])
        out = jax.eval_shape(fn, *args)
        if entry["kind"] == "gemm_acc":
            p = entry["params"]
            assert out[0].shape == (p["bm"], p["bn"]), entry["name"]
            # tile=block invariant on this testbed (EXPERIMENTS.md §Perf)
            assert (p["tm"], p["tn"], p["tk"]) == (p["bm"], p["bn"], p["bk"])


def test_gemm_acc_numerics_after_lowering_path():
    """The exact fn aot lowers computes C_in + A @ B."""
    fn, args = model.make_gemm_acc(8, 128, 128, 8, 128, 128, "f32")
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, args[0].shape, jnp.float32)
    b = jax.random.normal(key, args[1].shape, jnp.float32)
    c = jax.random.normal(key, args[2].shape, jnp.float32)
    (out,) = jax.jit(fn)(a, b, c)
    np.testing.assert_allclose(out, c + a @ b, rtol=1e-4, atol=1e-4)
