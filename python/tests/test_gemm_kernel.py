"""Pallas GEMM micro-kernels vs the pure-jnp oracle — core L1 signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gemm_tile, ref


def _rand(shape, dtype, seed):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


TILE_CASES = [
    # (m, n, k, tm, tn, tk)
    (8, 128, 128, 8, 128, 128),
    (16, 128, 256, 16, 128, 128),
    (32, 256, 256, 32, 128, 128),
    (64, 256, 512, 32, 128, 128),
    (128, 512, 512, 64, 128, 128),
    (64, 768, 768, 64, 128, 128),
]


@pytest.mark.parametrize("m,n,k,tm,tn,tk", TILE_CASES)
def test_gemm_matches_ref_f32(m, n, k, tm, tn, tk):
    a = _rand((m, k), jnp.float32, 0)
    b = _rand((k, n), jnp.float32, 1)
    got = gemm_tile.gemm(a, b, tm=tm, tn=tn, tk=tk)
    want = ref.gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n,k,tm,tn,tk", TILE_CASES[:3])
def test_gemm_matches_ref_bf16(m, n, k, tm, tn, tk):
    a = _rand((m, k), jnp.bfloat16, 2)
    b = _rand((k, n), jnp.bfloat16, 3)
    got = gemm_tile.gemm(a, b, tm=tm, tn=tn, tk=tk)  # f32 out (MMA contract)
    want = jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("m,n,k,tm,tn,tk", TILE_CASES[:4])
def test_gemm_acc_matches_ref(m, n, k, tm, tn, tk):
    a = _rand((m, k), jnp.float32, 4)
    b = _rand((k, n), jnp.float32, 5)
    c = _rand((m, n), jnp.float32, 6)
    got = gemm_tile.gemm_acc(a, b, c, tm=tm, tn=tn, tk=tk)
    want = ref.gemm_acc_ref(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_acc_chains_like_full_gemm():
    """Chaining gemm_acc over K super-blocks == one big GEMM.

    This is exactly what the Rust kernel constructor does at runtime, so
    it is the most load-bearing invariant in the python suite.
    """
    m, n, k, bk = 32, 256, 1024, 256
    a = _rand((m, k), jnp.float32, 7)
    b = _rand((k, n), jnp.float32, 8)
    c = jnp.zeros((m, n), jnp.float32)
    for i in range(k // bk):
        c = gemm_tile.gemm_acc(
            a[:, i * bk : (i + 1) * bk],
            b[i * bk : (i + 1) * bk, :],
            c,
            tm=32,
            tn=128,
            tk=128,
        )
    np.testing.assert_allclose(c, ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_gemm_padding_invariance():
    """Zero-padding M/K then cropping == unpadded result (constructor math)."""
    m, n, k = 20, 128, 200
    mp, kp = 32, 256
    a = _rand((m, k), jnp.float32, 9)
    b = _rand((k, n), jnp.float32, 10)
    ap = jnp.zeros((mp, kp), jnp.float32).at[:m, :k].set(a)
    bp = jnp.zeros((kp, n), jnp.float32).at[:k, :].set(b)
    got = gemm_tile.gemm(ap, bp, tm=8, tn=128, tk=128)[:m, :]
    np.testing.assert_allclose(got, ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_gemm_rejects_non_divisible_tiles():
    a = jnp.ones((30, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        gemm_tile.gemm(a, b, tm=8, tn=128, tk=128)


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 6),
    ni=st.integers(1, 3),
    ki=st.integers(1, 4),
    tm=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_hypothesis_shapes(mi, ni, ki, tm, seed):
    """Property sweep: any (tile-multiple) block shape matches the oracle."""
    m, n, k = mi * tm, ni * 128, ki * 128
    a = _rand((m, k), jnp.float32, seed)
    b = _rand((k, n), jnp.float32, seed + 1)
    got = gemm_tile.gemm(a, b, tm=tm, tn=128, tk=128)
    np.testing.assert_allclose(got, ref.gemm_ref(a, b), rtol=1e-4, atol=1e-4)
