"""Batched Pallas GEMM micro-kernel vs oracles — the native bgemm_acc L1.

The load-bearing invariants mirror the Rust runtime's use of the
artifact: K super-block chaining with the output fed back as the next
accumulator, and equality with a per-group gemm_acc loop (what the
host-loop fallback computes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bgemm_tile, gemm_tile


def _rand(shape, dtype, seed):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


TILE_CASES = [
    # (bb, m, n, k, tm, tn, tk)
    (4, 8, 128, 128, 8, 128, 128),
    (8, 8, 128, 128, 8, 128, 128),
    (2, 32, 256, 256, 32, 128, 128),
    (3, 64, 256, 512, 32, 128, 128),
]


@pytest.mark.parametrize("bb,m,n,k,tm,tn,tk", TILE_CASES)
def test_bgemm_acc_matches_einsum(bb, m, n, k, tm, tn, tk):
    a = _rand((bb, m, k), jnp.float32, 0)
    b = _rand((bb, k, n), jnp.float32, 1)
    c = _rand((bb, m, n), jnp.float32, 2)
    got = bgemm_tile.bgemm_acc(a, b, c, tm=tm, tn=tn, tk=tk)
    want = c + jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bb,m,n,k,tm,tn,tk", TILE_CASES[:2])
def test_bgemm_acc_matches_per_group_gemm_acc(bb, m, n, k, tm, tn, tk):
    """Native batched launch == the host-loop it replaces, group by group."""
    a = _rand((bb, m, k), jnp.float32, 3)
    b = _rand((bb, k, n), jnp.float32, 4)
    c = _rand((bb, m, n), jnp.float32, 5)
    got = bgemm_tile.bgemm_acc(a, b, c, tm=tm, tn=tn, tk=tk)
    for g in range(bb):
        want_g = gemm_tile.gemm_acc(a[g], b[g], c[g], tm=tm, tn=tn, tk=tk)
        np.testing.assert_allclose(got[g], want_g, rtol=1e-4, atol=1e-4)


def test_bgemm_acc_chains_like_full_contraction():
    """Chaining over K super-blocks == one big batched contraction.

    Exactly the Rust constructor's device-resident accumulator chain,
    batched: first call gets C_in = 0, later calls feed the previous
    output back in.
    """
    bb, m, n, k, bk = 3, 16, 128, 512, 128
    a = _rand((bb, m, k), jnp.float32, 6)
    b = _rand((bb, k, n), jnp.float32, 7)
    c = jnp.zeros((bb, m, n), jnp.float32)
    for i in range(k // bk):
        c = bgemm_tile.bgemm_acc(
            a[:, :, i * bk : (i + 1) * bk],
            b[:, i * bk : (i + 1) * bk, :],
            c,
            tm=8,
            tn=128,
            tk=128,
        )
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(c, want, rtol=1e-4, atol=1e-4)


def test_bgemm_acc_bf16_inputs_f32_accumulator():
    bb, m, n, k = 2, 16, 128, 128
    a = _rand((bb, m, k), jnp.bfloat16, 8)
    b = _rand((bb, k, n), jnp.bfloat16, 9)
    c = _rand((bb, m, n), jnp.float32, 10)
    got = bgemm_tile.bgemm_acc(a, b, c, tm=8, tn=128, tk=128)
    assert got.dtype == jnp.float32
    want = c + jnp.einsum(
        "bmk,bkn->bmn", a.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_bgemm_acc_rejects_non_divisible_tiles():
    a = jnp.ones((2, 30, 128), jnp.float32)
    b = jnp.ones((2, 128, 128), jnp.float32)
    c = jnp.zeros((2, 30, 128), jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        bgemm_tile.bgemm_acc(a, b, c, tm=8, tn=128, tk=128)


@settings(max_examples=15, deadline=None)
@given(
    bb=st.integers(1, 5),
    mi=st.integers(1, 4),
    ki=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_bgemm_hypothesis_shapes(bb, mi, ki, seed):
    """Property sweep: any (tile-multiple) batched block matches einsum."""
    m, n, k = mi * 8, 128, ki * 128
    a = _rand((bb, m, k), jnp.float32, seed)
    b = _rand((bb, k, n), jnp.float32, seed + 1)
    c = _rand((bb, m, n), jnp.float32, seed + 2)
    got = bgemm_tile.bgemm_acc(a, b, c, tm=8, tn=128, tk=128)
    want = c + jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
