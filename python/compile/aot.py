"""AOT bridge: lower every manifest micro-kernel to HLO text artifacts.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once via `make artifacts`; it is a no-op when artifacts are newer
than the inputs. Python never runs on the request path — the rust binary
loads `artifacts/manifest.json` + `artifacts/*.hlo.txt` at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: all artifacts are single-output, and an
    # untupled output buffer can be fed straight back as the next call's
    # accumulator input (device-resident K-chaining in the rust
    # constructor) without a tuple unpack + host round trip.
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _io_spec(args, out_avals):
    def one(a):
        return {"shape": list(a.shape), "dtype": str(a.dtype)}

    return [one(a) for a in args], [one(a) for a in out_avals]


def lower_entry(entry: dict) -> tuple[str, dict]:
    """Lower one manifest entry; returns (hlo_text, io-annotated entry)."""
    kind = entry["kind"]
    params = dict(entry["params"])
    builder = model.BUILDERS[kind]
    fn, args = builder(**params)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_avals = jax.eval_shape(fn, *args)
    inputs, outputs = _io_spec(args, out_avals)
    annotated = {
        "name": entry["name"],
        "kind": kind,
        "params": params,
        "file": f"{entry['name']}.hlo.txt",
        "inputs": inputs,
        "outputs": outputs,
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }
    return text, annotated


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--manifest",
        default=os.path.join(os.path.dirname(__file__), "microkernels.json"),
    )
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated entry names to lower"
    )
    ns = ap.parse_args()

    with open(ns.manifest) as f:
        spec = json.load(f)
    only = set(ns.only.split(",")) if ns.only else None

    os.makedirs(ns.out_dir, exist_ok=True)
    out_entries = []
    t_all = time.time()
    for entry in spec["entries"]:
        if only and entry["name"] not in only:
            continue
        t0 = time.time()
        text, annotated = lower_entry(entry)
        path = os.path.join(ns.out_dir, annotated["file"])
        with open(path, "w") as f:
            f.write(text)
        out_entries.append(annotated)
        print(
            f"  lowered {entry['name']:<32} {len(text):>9} chars "
            f"in {time.time() - t0:5.1f}s"
        )
    manifest_out = {
        "generated_by": "python/compile/aot.py",
        "jax_version": jax.__version__,
        "entries": out_entries,
    }
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest_out, f, indent=1)
    print(
        f"wrote {len(out_entries)} artifacts + manifest.json "
        f"to {ns.out_dir} in {time.time() - t_all:.1f}s"
    )


if __name__ == "__main__":
    main()
