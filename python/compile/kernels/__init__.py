# L1: Pallas micro-kernels for the paper compute hot-spots + jnp oracles.
from . import bgemm_tile, gemm_epilogue, gemm_tile, ref, softmax_tile  # noqa: F401
