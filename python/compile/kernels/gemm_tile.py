"""L1: blocked GEMM micro-kernels as Pallas kernels.

These are the Vortex L0 micro-kernels for the *real* (CPU-PJRT) testbed:
each (BM, BN, BK, tm, tn, tk, dtype) variant is lowered once by aot.py to
a static-shape HLO module; the Rust kernel constructor composes them over
the runtime grid (pad -> tile loop -> accumulate), exactly the paper's
runtime stage.

Hardware adaptation (DESIGN.md §3): the Pallas BlockSpec expresses the
HBM->VMEM tiling the paper expressed with CUDA threadblocks; the inner
(tm, tn, tk) tile is the MXU/ISA-granularity analog (FilterByISA in
Algorithm 2 constrains these to multiples of the pallas sublane/lane
tile, 8x128 for f32). interpret=True throughout — real-TPU lowering
emits Mosaic custom-calls the CPU PJRT client cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _check_tiles(m, n, k, tm, tn, tk):
    if m % tm or n % tn or k % tk:
        raise ValueError(
            f"block ({m},{n},{k}) not divisible by inner tile ({tm},{tn},{tk})"
        )


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid (M/tm, N/tn, K/tk), K innermost; f32 VMEM accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gemm_acc_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, k_steps: int):
    """Accumulate form O = C_in + A @ B; C_in seeds the accumulator."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "out_dtype"))
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    tm: int,
    tn: int,
    tk: int,
    out_dtype=jnp.float32,
) -> jax.Array:
    """C = A @ B over one micro-kernel block, pallas-tiled (tm, tn, tk).

    bf16 inputs with f32 output model the MXU/Tensor-Core contract
    (low-precision multiply, f32 accumulate).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (k, k2)
    _check_tiles(m, n, k, tm, tn, tk)
    k_steps = k // tk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // tm, n // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def gemm_acc(
    a: jax.Array,
    b: jax.Array,
    c_in: jax.Array,
    *,
    tm: int,
    tn: int,
    tk: int,
) -> jax.Array:
    """O = C_in + A @ B — the grid-constructor accumulate micro-kernel.

    The Rust runtime chains these over K super-blocks: the first call gets
    C_in = 0, subsequent calls feed the previous output back in. Output
    dtype follows C_in (f32 on the hot path).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (k, k2)
    assert c_in.shape == (m, n), (c_in.shape, m, n)
    _check_tiles(m, n, k, tm, tn, tk)
    k_steps = k // tk
    return pl.pallas_call(
        functools.partial(_gemm_acc_kernel, k_steps=k_steps),
        grid=(m // tm, n // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c_in.dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(a, b, c_in)
