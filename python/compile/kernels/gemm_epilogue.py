"""L1: fused GEMM + bias + activation epilogue as a Pallas kernel.

Vortex's kernel constructor fuses the epilogue of the *last* K super-block
into the micro-kernel (the paper's Store stage customization, Table 1).
This variant is used by the BERT-serving example for the MLP up-projection
(bias + GELU) so the activation never round-trips through HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def _apply_act(x, act: str):
    if act == "gelu":
        inner = _GELU_C * (x + 0.044715 * x * x * x)
        return 0.5 * x * (1.0 + jnp.tanh(inner))
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "none":
        return x
    raise ValueError(f"unknown act {act!r}")


def _kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, k_steps: int, act: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = _apply_act(out, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "act"))
def gemm_bias_act(
    a: jax.Array,
    b: jax.Array,
    bias: jax.Array,
    *,
    tm: int,
    tn: int,
    tk: int,
    act: str = "gelu",
) -> jax.Array:
    """C = act(A @ B + bias), fused in the store stage of the K loop."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and bias.shape == (n,), (a.shape, b.shape, bias.shape)
    if m % tm or n % tn or k % tk:
        raise ValueError(
            f"block ({m},{n},{k}) not divisible by tile ({tm},{tn},{tk})"
        )
    k_steps = k // tk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, act=act),
        grid=(m // tm, n // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(a, b, bias)
