"""Pure-jnp correctness oracles for the Pallas micro-kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops (no pallas, no lax.conv fast paths where
avoidable) so the two code paths are genuinely independent. pytest +
hypothesis compare kernel vs ref with assert_allclose.
"""

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation regardless of input dtype."""
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(a.dtype)


def gemm_acc_ref(a: jax.Array, b: jax.Array, c_in: jax.Array) -> jax.Array:
    """C = C_in + A @ B — the accumulate form used by the grid constructor."""
    acc = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    return (c_in.astype(jnp.float32) + acc).astype(c_in.dtype)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (same formula the pallas epilogue uses)."""
    x32 = x.astype(jnp.float32)
    inner = 0.7978845608028654 * (x32 + 0.044715 * x32 * x32 * x32)
    return (0.5 * x32 * (1.0 + jnp.tanh(inner))).astype(x.dtype)


def gemm_bias_act_ref(
    a: jax.Array, b: jax.Array, bias: jax.Array, act: str = "gelu"
) -> jax.Array:
    """C = act(A @ B + bias) — fused epilogue reference."""
    out = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    out = out + bias.astype(jnp.float32)[None, :]
    if act == "gelu":
        out = gelu_ref(out)
    elif act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return out.astype(a.dtype)


def softmax_ref(x: jax.Array) -> jax.Array:
    """Row softmax over the last axis, f32 internally."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def im2col_ref(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """NHWC input -> (N*OH*OW, KH*KW*C) patch matrix, valid padding.

    Built from static slices + concatenate only, so it is a trustworthy
    oracle for the implicit-GEMM convolution path.
    """
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            cols.append(patch.reshape(n * oh * ow, c))
    # (N*OH*OW, KH*KW*C) with filter taps in (i, j) row-major order
    return jnp.concatenate(cols, axis=-1)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Direct NHWC valid convolution, f32 accumulation.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout) -> (N, OH, OW, Cout).
    Implemented as an explicit loop over filter taps (independent of both
    im2col and lax.conv), to serve as the oracle for the implicit-GEMM path.
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h - kh) // stride + 1
    ow = (wdt - kw) // stride + 1
    acc = jnp.zeros((n, oh, ow, cout), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            acc = acc + jnp.einsum(
                "nhwc,co->nhwo",
                patch.astype(jnp.float32),
                w[i, j].astype(jnp.float32),
            )
    return acc.astype(x.dtype)


def encoder_layer_ref(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wo: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    n_heads: int,
) -> jax.Array:
    """Minimal transformer encoder layer (attn + GELU MLP, residuals)."""
    s, d = x.shape
    hd = d // n_heads
    q = gemm_ref(x, wq).reshape(s, n_heads, hd).transpose(1, 0, 2)
    k = gemm_ref(x, wk).reshape(s, n_heads, hd).transpose(1, 0, 2)
    v = gemm_ref(x, wv).reshape(s, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hsd,htd->hst", q, k) / jnp.sqrt(jnp.float32(hd))
    probs = softmax_ref(scores)
    ctx = jnp.einsum("hst,htd->hsd", probs, v).transpose(1, 0, 2).reshape(s, d)
    attn_out = gemm_ref(ctx, wo) + x
    h = gemm_bias_act_ref(attn_out, w1, b1, act="gelu")
    out = gemm_ref(h, w2) + b2[None, :] + attn_out
    return out
