"""L1: batched blocked GEMM micro-kernel as a Pallas kernel.

One `bgemm_acc` launch contracts a whole stack of (bm, bk) x (bk, bn)
blocks — the batch/group/head loop that `rust/src/runtime` used to walk
on the host, one `gemm_acc` launch per group, now rides the grid's
leading axis on-device. `GroupedConv2d` and `FusedAttention` alias
`BatchedGemm::artifact_name`, so a single artifact family serves grouped
conv (batch = groups), attention (batch = batch*heads), and plain
batched GEMM.

Same contract as gemm_tile.gemm_acc otherwise: C_in seeds an f32 VMEM
accumulator, K is the innermost grid axis, the untupled output buffer
feeds back as the next call's accumulator input. interpret=True for the
CPU PJRT testbed (see gemm_tile.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gemm_tile import _check_tiles


def _bgemm_acc_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid (B, M/tm, N/tn, K/tk), K innermost; one batch slab per step."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = c_ref[0].astype(jnp.float32)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def bgemm_acc(
    a: jax.Array,
    b: jax.Array,
    c_in: jax.Array,
    *,
    tm: int,
    tn: int,
    tk: int,
) -> jax.Array:
    """O[g] = C_in[g] + A[g] @ B[g] for every g in the leading batch axis.

    The Rust runtime chains these over K super-blocks exactly like the
    scalar form — first call gets C_in = 0, later calls feed the previous
    output back in — but each launch covers `batch` groups at once, so a
    G-group conv costs ceil(G / bb) launch chains instead of G.
    """
    batch, m, k = a.shape
    b2, k2, n = b.shape
    assert batch == b2, (batch, b2)
    assert k == k2, (k, k2)
    assert c_in.shape == (batch, m, n), (c_in.shape, batch, m, n)
    _check_tiles(m, n, k, tm, tn, tk)
    k_steps = k // tk
    return pl.pallas_call(
        functools.partial(_bgemm_acc_kernel, k_steps=k_steps),
        grid=(batch, m // tm, n // tn, k_steps),
        in_specs=[
            pl.BlockSpec((1, tm, tk), lambda bb, i, j, kk: (bb, i, kk)),
            pl.BlockSpec((1, tk, tn), lambda bb, i, j, kk: (bb, kk, j)),
            pl.BlockSpec((1, tm, tn), lambda bb, i, j, kk: (bb, i, j)),
        ],
        out_specs=pl.BlockSpec((1, tm, tn), lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m, n), c_in.dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(a, b, c_in)
