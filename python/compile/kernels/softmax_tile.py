"""L1: row-softmax Pallas kernel (attention score normalization).

Tiled over rows only; each grid step owns (tr, N) so the reduction stays
inside one VMEM block — the dynamic dimension at serving time is the row
count (sequence length), which the Rust side pads to the row tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tr",))
def softmax(x: jax.Array, *, tr: int) -> jax.Array:
    """Row softmax over the last axis of a 2-D block, row tile tr."""
    r, n = x.shape
    if r % tr:
        raise ValueError(f"rows {r} not divisible by row tile {tr}")
    return pl.pallas_call(
        _softmax_kernel,
        grid=(r // tr,),
        in_specs=[pl.BlockSpec((tr, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), x.dtype),
        interpret=True,
    )(x)
