"""L2: jax compute graphs that aot.py lowers to HLO artifacts.

Everything here is a *static-shape* function-of-arrays built on the L1
Pallas kernels (kernels/*.py). aot.py lowers one variant per manifest
entry; the Rust runtime composes the static blocks over the dynamic
runtime shape (pad -> grid loop -> accumulate), which is Vortex's
kernel-constructor runtime stage.

Python is build-time only: nothing in this module runs on the request
path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import bgemm_tile, gemm_epilogue, gemm_tile, ref, softmax_tile

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def dtype_of(name: str):
    return _DTYPES[name]


# ---------------------------------------------------------------------------
# Micro-kernel entry points (one AOT artifact per (shape, tile, dtype))
# ---------------------------------------------------------------------------

def make_gemm(bm, bn, bk, tm, tn, tk, in_dtype="f32"):
    """C[bm,bn] = A[bm,bk] @ B[bk,bn] — plain micro-kernel block."""
    dt = dtype_of(in_dtype)

    def fn(a, b):
        return (gemm_tile.gemm(a, b, tm=tm, tn=tn, tk=tk),)

    args = (
        jax.ShapeDtypeStruct((bm, bk), dt),
        jax.ShapeDtypeStruct((bk, bn), dt),
    )
    return fn, args


def make_gemm_acc(bm, bn, bk, tm, tn, tk, in_dtype="f32"):
    """O[bm,bn] = C_in[bm,bn] + A[bm,bk] @ B[bk,bn] — accumulate block.

    The accumulator is always f32; this is the hot-path micro-kernel the
    Rust grid constructor chains over K super-blocks.
    """
    dt = dtype_of(in_dtype)

    def fn(a, b, c_in):
        return (gemm_tile.gemm_acc(a, b, c_in, tm=tm, tn=tn, tk=tk),)

    args = (
        jax.ShapeDtypeStruct((bm, bk), dt),
        jax.ShapeDtypeStruct((bk, bn), dt),
        jax.ShapeDtypeStruct((bm, bn), jnp.float32),
    )
    return fn, args


def make_bgemm_acc(bb, bm, bn, bk, tm, tn, tk, in_dtype="f32"):
    """O[bb,bm,bn] = C_in + A[bb,bm,bk] @ B[bb,bk,bn] — batched accumulate.

    The rank-4 analog of make_gemm_acc: one launch contracts `bb` group
    blocks (conv groups / attention heads / batched GEMM batch), so the
    Rust runtime's batch loop runs on-device. Named per
    BatchedGemm::artifact_name: bgemm_acc_{bb}x{bm}x{bn}x{bk}_{dtype}.
    """
    dt = dtype_of(in_dtype)

    def fn(a, b, c_in):
        return (bgemm_tile.bgemm_acc(a, b, c_in, tm=tm, tn=tn, tk=tk),)

    args = (
        jax.ShapeDtypeStruct((bb, bm, bk), dt),
        jax.ShapeDtypeStruct((bb, bk, bn), dt),
        jax.ShapeDtypeStruct((bb, bm, bn), jnp.float32),
    )
    return fn, args


def make_gemm_bias_act(bm, bn, bk, tm, tn, tk, act="gelu", in_dtype="f32"):
    """O = act(A @ B + bias) — fused-epilogue block (store-stage fusion)."""
    dt = dtype_of(in_dtype)

    def fn(a, b, bias):
        return (
            gemm_epilogue.gemm_bias_act(a, b, bias, tm=tm, tn=tn, tk=tk, act=act),
        )

    args = (
        jax.ShapeDtypeStruct((bm, bk), dt),
        jax.ShapeDtypeStruct((bk, bn), dt),
        jax.ShapeDtypeStruct((bn,), dt),
    )
    return fn, args


def make_softmax(rows, cols, tr):
    """Row softmax block used by the attention path."""

    def fn(x):
        return (softmax_tile.softmax(x, tr=tr),)

    args = (jax.ShapeDtypeStruct((rows, cols), jnp.float32),)
    return fn, args


# ---------------------------------------------------------------------------
# Implicit-GEMM convolution: im2col (data layout) + pallas GEMM (compute)
# ---------------------------------------------------------------------------

def conv2d_im2col(x, w, *, tm, tn, tk):
    """NHWC valid conv via im2col + the pallas GEMM micro-kernel.

    This is how Vortex maps Conv loops into the same rKernel recursion as
    GEMM (paper §4.2): the patch-matrix rows are the parallel/spatial
    loops, the (kh*kw*cin) axis is the temporal-reduction loop.
    """
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    patches = ref.im2col_ref(x, kh, kw)  # (n*oh*ow, kh*kw*cin)
    # match im2col tap order: rows are (i,j) taps each of width cin
    wmat = w.reshape(kh * kw * cin, cout)
    out = gemm_tile.gemm(patches, wmat, tm=tm, tn=tn, tk=tk)
    oh = h - kh + 1
    ow = wd - kw + 1
    return out.reshape(n, oh, ow, cout).astype(x.dtype)


def make_conv2d(n, h, w, cin, cout, kh, kw, tm, tn, tk, in_dtype="f32"):
    """Conv micro-block artifact (fixed spatial extent, valid padding)."""
    dt = dtype_of(in_dtype)

    def fn(x, wgt):
        return (conv2d_im2col(x, wgt, tm=tm, tn=tn, tk=tk),)

    args = (
        jax.ShapeDtypeStruct((n, h, w, cin), dt),
        jax.ShapeDtypeStruct((kh, kw, cin, cout), dt),
    )
    return fn, args


# ---------------------------------------------------------------------------
# Bucketed whole-layer graph: the static-shape baseline for real serving
# ---------------------------------------------------------------------------

def encoder_layer(x, params, *, n_heads, tm, tn, tk):
    """Transformer encoder layer built on the pallas kernels.

    Used two ways: (a) AOT'd at a few fixed sequence buckets as the
    "static-compile + pad" baseline the paper argues against, and
    (b) as the shape/numerics test target for the model-level path.
    """
    wq, wk, wv, wo, w1, b1, w2, b2 = params
    s, d = x.shape
    hd = d // n_heads
    q = gemm_tile.gemm(x, wq, tm=tm, tn=tn, tk=tk)
    k = gemm_tile.gemm(x, wk, tm=tm, tn=tn, tk=tk)
    v = gemm_tile.gemm(x, wv, tm=tm, tn=tn, tk=tk)

    def split(t):
        return t.reshape(s, n_heads, hd).transpose(1, 0, 2)

    qh, kh_, vh = split(q), split(k), split(v)
    scores = jnp.einsum("hsd,htd->hst", qh, kh_) / jnp.sqrt(jnp.float32(hd))
    probs = softmax_tile.softmax(scores.reshape(n_heads * s, s), tr=min(s, 8))
    probs = probs.reshape(n_heads, s, s)
    ctx = jnp.einsum("hst,htd->hsd", probs, vh).transpose(1, 0, 2).reshape(s, d)
    attn_out = gemm_tile.gemm(ctx, wo, tm=tm, tn=tn, tk=tk) + x
    h = gemm_epilogue.gemm_bias_act(
        attn_out, w1, b1, tm=tm, tn=tn, tk=tk, act="gelu"
    )
    out = (
        gemm_tile.gemm(h, w2, tm=tm, tn=min(tn, d), tk=tk)
        + b2[None, :]
        + attn_out
    )
    return out


def encoder_params_spec(d, ff, dtype=jnp.float32):
    """ShapeDtypeStructs for encoder_layer params, in call order."""
    sd = jax.ShapeDtypeStruct
    return (
        sd((d, d), dtype),
        sd((d, d), dtype),
        sd((d, d), dtype),
        sd((d, d), dtype),
        sd((d, ff), dtype),
        sd((ff,), dtype),
        sd((ff, d), dtype),
        sd((d,), dtype),
    )


def make_encoder_layer(seq, d, ff, n_heads, tm, tn, tk):
    """Bucketed encoder-layer artifact at a fixed sequence length."""

    def fn(x, *params):
        return (encoder_layer(x, params, n_heads=n_heads, tm=tm, tn=tn, tk=tk),)

    args = (jax.ShapeDtypeStruct((seq, d), jnp.float32),) + encoder_params_spec(
        d, ff
    )
    return fn, args


# Registry used by aot.py: manifest "kind" -> builder.
BUILDERS = {
    "gemm": make_gemm,
    "gemm_acc": make_gemm_acc,
    "bgemm_acc": make_bgemm_acc,
    "gemm_bias_act": make_gemm_bias_act,
    "softmax": make_softmax,
    "conv2d": make_conv2d,
    "encoder_layer": make_encoder_layer,
}
