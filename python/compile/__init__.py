# Build-time-only package: L2 jax graphs + L1 pallas kernels + AOT bridge.
