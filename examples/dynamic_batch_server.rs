//! Threaded dynamic-batch server: producer threads submit requests with
//! random sequence lengths over a channel; the coordinator thread forms
//! batches (size/window policy), selects a micro-kernel per merged
//! shape, and executes — on the REAL PJRT engine when artifacts exist,
//! falling back to the simulated A100 otherwise.
//!
//! Demonstrates the L3 runtime as an actual server: queueing,
//! batching, backpressure (bounded channel), per-request latency.
//!
//! Run with: cargo run --release --example dynamic_batch_server \
//!             [--requests 64] [--max-batch 8] [--window-ms 2]

use std::path::Path;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use vortex::compiler::{compile, CompileOpts};
use vortex::coordinator::metrics::Metrics;
use vortex::coordinator::{HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{Contraction, DType};
use vortex::profiler::SimProfiler;
use vortex::runtime::{build_real_library, RealEngine};
use vortex::sim::Simulator;
use vortex::util::cli::Args;
use vortex::util::rng::Rng;

struct Req {
    #[allow(dead_code)]
    id: usize,
    rows: usize,
    t_submit: Instant,
}

enum Exec {
    Real { engine: RealEngine },
    Sim { sim: Simulator },
}

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 64);
    let max_batch = args.get_usize("max-batch", 8);
    let window = Duration::from_millis(args.get_u64("window-ms", 2));
    let (n, k) = (768usize, 256usize); // served GEMM width

    // Engine + library: real if artifacts are present.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let (exec, selector) = if dir.join("manifest.json").exists() {
        let engine = RealEngine::load(&dir).expect("engine");
        let hw = presets::cpu_pjrt();
        let lib = build_real_library(&engine, &hw, DType::F32, 1).expect("library");
        println!("serving on the REAL PJRT engine ({} blocks)", lib.kernels.len());
        (Exec::Real { engine }, Selector::new(hw, vec![lib]))
    } else {
        let hw = presets::a100();
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 7));
        let lib = compile(
            &hw,
            vortex::ir::OpKind::Gemm,
            DType::F32,
            &AnalyzerConfig::default_for(&hw),
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        println!("artifacts missing; serving on the simulated A100");
        (Exec::Sim { sim: Simulator::new(hw.clone(), 7) }, Selector::new(hw, vec![lib]))
    };

    // Bounded channel = backpressure: producers block when the
    // coordinator falls behind.
    let (tx, rx) = mpsc::sync_channel::<Req>(max_batch * 4);

    // Producer thread: Poisson-ish arrivals, random sequence lengths.
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(99);
        for id in 0..n_requests {
            let gap = rng.exp(1.5e-3);
            thread::sleep(Duration::from_secs_f64(gap));
            let rows = rng.usize(4, 160);
            tx.send(Req { id, rows, t_submit: Instant::now() }).unwrap();
        }
    });

    // Coordinator loop (the serving hot path — python-free).
    let mut rng = Rng::new(3);
    let a_max = rng.normal_f32_vec(2048 * k);
    let w: Vec<f32> = rng.normal_f32_vec(k * n).iter().map(|x| x * 0.05).collect();
    let mut metrics = Metrics::default();
    let mut served = 0usize;
    let mut batches = 0usize;
    let t_run = Instant::now();
    while served < n_requests {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let deadline = Instant::now() + window;
        let mut batch = vec![first];
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        let rows: usize = batch.iter().map(|r| r.rows).sum();
        let c = Contraction { m: rows, n, k, dtype: DType::F32 };
        let sel = selector.select(c, HwMode::Adaptive).expect("select");
        let kern = selector.kernel(&sel);
        let t_exec = Instant::now();
        let exec_secs = match &exec {
            Exec::Real { engine } => {
                let rows_cap = rows.min(2048);
                engine
                    .gemm_dynamic(
                        &a_max[..rows_cap * k],
                        &w,
                        (rows_cap, n, k),
                        kern.l1.to3(),
                        DType::F32,
                    )
                    .expect("gemm");
                t_exec.elapsed().as_secs_f64()
            }
            Exec::Sim { sim } => {
                sim.execute(selector.libraries[sel.lib].dtype, &selector.chain(&sel))
            }
        };
        let done = Instant::now();
        for r in &batch {
            metrics.record(
                done.duration_since(r.t_submit).as_secs_f64(),
                sel.select_secs / batch.len() as f64,
                exec_secs / batch.len() as f64,
                c.flops() * r.rows as f64 / rows as f64,
            );
        }
        served += batch.len();
        batches += 1;
    }
    metrics.span_secs = t_run.elapsed().as_secs_f64();
    producer.join().unwrap();

    println!(
        "served {} requests in {} batches (mean batch {:.2})",
        served,
        batches,
        served as f64 / batches as f64
    );
    println!("{}", metrics.summary());
}
