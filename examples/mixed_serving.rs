//! Multi-op serving demo: BERT token traffic interleaved with vision
//! bursts, served three ways over the same trace — the compile-time
//! dispatch table (zero warm-up), the bucketed plan cache (one fresh
//! scan per bucket), and fresh per-batch selection — to show identical
//! plans at a fraction of the scheduling cost.
//!
//! Run with: cargo run --release --example mixed_serving \
//!             [--requests 600] [--mean-gap-us 400] [--seed 7]

use vortex::bench::exp_serve::{identical_selections, warm_hit_rate};
use vortex::hw::presets;
use vortex::ir::DType;
use vortex::serve::{scenario, serve_mixed_trace, SimLaneEngine};
use vortex::sim::Simulator;
use vortex::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n_req = args.get_usize("requests", 600);
    let gap = args.get_f64("mean-gap-us", 400.0) * 1e-6;
    let seed = args.get_u64("seed", 7);

    // Offline: the scenario's shared demo selector — a GEMM library
    // (serves conv via implicit GEMM) and a batched-GEMM library
    // (serves grouped conv + attention via the alias fixpoint).
    let hw = presets::a100();
    let selector = scenario::demo_selector(seed);

    let trace = scenario::mixed_trace(n_req, gap, seed, DType::F32);
    let serve_cfg = scenario::serving_config();

    let mut engine = SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
    let table = serve_mixed_trace(
        &mut engine,
        &selector,
        &serve_cfg.with_dispatch(scenario::dispatch_config()),
        &trace,
    );
    let mut engine = SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
    let cached = serve_mixed_trace(&mut engine, &selector, &serve_cfg, &trace);
    let mut engine = SimLaneEngine { sim: Simulator::new(hw, seed) };
    let fresh = serve_mixed_trace(&mut engine, &selector, &serve_cfg.without_cache(), &trace);

    println!(
        "== mixed serving: {} requests across {} lanes ==",
        cached.count(),
        cached.lanes.len()
    );
    for l in &cached.lanes {
        let (p50, _, p99) = l.metrics.latency_percentiles();
        println!(
            "  lane {:<12} {:>4} reqs in {:>4} batches  p50 {:>8.2}ms  p99 {:>8.2}ms",
            l.class.name(),
            l.metrics.count(),
            l.batches,
            p50 * 1e3,
            p99 * 1e3,
        );
    }
    let build = table.dispatch_build.clone().unwrap_or_default();
    println!(
        "dispatch table: {} table / {} cache / {} fresh — warm-start {:.1}% \
         ({} cells merged from {}, built offline in {:.1} ms)",
        table.dispatch.table,
        table.dispatch.cache,
        table.dispatch.fresh,
        100.0 * table.dispatch.warm_start_rate(),
        build.cells,
        build.cells_enumerated,
        build.build_secs * 1e3,
    );
    println!(
        "plan cache: hit rate {:.1}% overall, {:.1}% after warmup ({} buckets missed)",
        100.0 * cached.cache.hit_rate(),
        100.0 * warm_hit_rate(&cached),
        cached.cache.misses,
    );
    println!(
        "scheduling seconds: {:.2e} table vs {:.2e} cached vs {:.2e} fresh",
        table.total_sched_secs(),
        cached.total_sched_secs(),
        fresh.total_sched_secs(),
    );
    println!(
        "identical per-request selections: {}",
        identical_selections(&table, &fresh) && identical_selections(&cached, &fresh),
    );
}
