//! Quickstart: the whole Vortex pipeline in ~40 lines.
//!
//! 1. Pick a hardware target (simulated A100 here — no GPU needed).
//! 2. Run the sample-free offline stage once (candidates -> hybrid
//!    analysis -> micro-kernel library). No shape samples anywhere.
//! 3. At "runtime", throw arbitrary dynamic shapes at the selector and
//!    watch it construct a kernel (tile chain + grid + padding) per
//!    shape in microseconds.
//!
//! Run with: cargo run --release --example quickstart

use vortex::compiler::{compile, CompileOpts};
use vortex::coordinator::{HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{Contraction, DType};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;

fn main() {
    // -- offline stage (once per hardware, never re-run per shape) -----
    let hw = presets::a100();
    let analyzer = AnalyzerConfig::default_for(&hw); // E: L0, L1 on GPU
    let mut profiler = SimProfiler::new(Simulator::new(hw.clone(), 7));
    let report = compile(
        &hw,
        vortex::ir::OpKind::Gemm,
        DType::F16,
        &analyzer,
        &mut profiler,
        &CompileOpts::default(),
    );
    println!(
        "offline: {} candidates -> {} micro-kernels ({} profile queries, ~{:.1}s modeled on-target)",
        report.candidates_total,
        report.library.kernels.len(),
        report.profile_queries,
        report.offline_secs,
    );

    // -- runtime stage: any shape, no samples, no retuning --------------
    let selector = Selector::new(hw.clone(), vec![report.library]);
    for (m, n, k) in [(1, 768, 768), (77, 2304, 768), (333, 4096, 4096), (100_000, 16, 64)] {
        let c = Contraction { m, n, k, dtype: DType::F16 };
        let sel = selector.select(c, HwMode::Adaptive).expect("select");
        let kern = selector.kernel(&sel);
        println!(
            "GEMM {m}x{n}x{k}: block {:?} (L0 {:?}) grid {:?} padded {:?} est {:.1}us (selected in {:.1}us)",
            kern.l1,
            kern.l0,
            sel.grid,
            sel.padded,
            sel.est_secs * 1e6,
            sel.select_secs * 1e6,
        );
    }
}
