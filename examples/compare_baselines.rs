//! Side-by-side engine comparison on a shape sweep: Vortex vs cuBLAS /
//! CUTLASS / DietCode on the simulated A100 (CUDA cores, the one mode
//! where all four engines apply).
//!
//! Prints a per-shape table (times + who wins) — a compact, readable
//! version of the Fig. 12 scatter.
//!
//! Run with: cargo run --release --example compare_baselines [--seed 7]

use vortex::baselines::cutlass::Cutlass;
use vortex::baselines::dietcode::DietCode;
use vortex::baselines::vendor::VendorLib;
use vortex::baselines::PlanEngine;
use vortex::bench::harness::{dietcode_default_samples, vortex_engine, Testbed};
use vortex::ir::{Contraction, DType};
use vortex::profiler::SimProfiler;
use vortex::sim::Simulator;
use vortex::util::cli::Args;
use vortex::util::table::Table;

fn main() {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 7);
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);

    eprintln!("compiling Vortex + tuning DietCode (offline stages)...");
    let vortex = vortex_engine(tb, seed);
    let cublas = VendorLib::cublas(&hw, "cuda_core_f32");
    let cutlass = Cutlass::new(&hw, "cuda_core_f32");
    let mut prof = SimProfiler::new(sim.clone());
    let dietcode = DietCode::tune(
        &hw,
        "cuda_core_f32",
        &dietcode_default_samples(false),
        400,
        &mut prof,
        seed,
    );

    let shapes: &[(usize, usize, usize, &str)] = &[
        (1, 768, 768, "decode step"),
        (7, 2304, 768, "tiny batch QKV"),
        (128, 768, 2304, "BERT GEMM-1 (in DietCode samples)"),
        (100, 768, 2304, "BERT GEMM-1 (out of samples)"),
        (512, 3072, 768, "MLP up"),
        (4096, 4096, 4096, "square steady-state"),
        (300000, 16, 64, "GNN aggregate"),
        (35, 8448, 2560, "DeepBench"),
    ];

    let mut t = Table::new(
        "engine comparison (simulated A100, CUDA cores, times in us)",
        &["shape", "what", "vortex", "cublas", "cutlass", "dietcode", "winner"],
    );
    for &(m, n, k, what) in shapes {
        let c = Contraction { m, n, k, dtype: DType::F32 };
        let tv = vortex.time(&sim, c);
        let engines: [(&str, f64); 4] = [
            ("vortex", tv),
            ("cublas", sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead()),
            ("cutlass", sim.execute(DType::F32, &cutlass.plan(c)) + cutlass.dispatch_overhead()),
            ("dietcode", sim.execute(DType::F32, &dietcode.plan(c)) + dietcode.dispatch_overhead()),
        ];
        let winner = engines
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        t.row(vec![
            format!("{}x{}x{}", m, n, k),
            what.into(),
            format!("{:.1}", engines[0].1 * 1e6),
            format!("{:.1}", engines[1].1 * 1e6),
            format!("{:.1}", engines[2].1 * 1e6),
            format!("{:.1}", engines[3].1 * 1e6),
            winner.into(),
        ]);
    }
    t.print();
}
