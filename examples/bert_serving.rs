//! End-to-end REAL serving driver (EXPERIMENTS.md §E2E).
//!
//! Serves a BERT-mini-style encoder stack (d=256, ff=1024, 4 layers of
//! GEMMs) over dynamically-sized requests on the REAL PJRT engine:
//! AOT Pallas micro-kernels selected per batch shape by the Vortex
//! coordinator, composed by the grid constructor, executed through
//! `xla`/PJRT. Python is not involved anywhere in this binary.
//!
//! For every batch we also run the "static bucket" strategy the paper
//! argues against (pad every batch to a fixed 256-row bucket) to show
//! the dynamic-shape win on real hardware, and we verify numerics of
//! the first batch against a host reference.
//!
//! Run with: make artifacts && cargo run --release --example bert_serving

use std::path::Path;
use std::time::Instant;

use vortex::coordinator::metrics::Metrics;
use vortex::coordinator::{HwMode, Selector};
use vortex::hw::presets;
use vortex::ir::{Contraction, DType};
use vortex::runtime::{build_real_library, gemm_host_ref, RealEngine};
use vortex::util::cli::Args;
use vortex::util::rng::Rng;

/// One encoder layer = 4 GEMM widths (n, k) at dynamic row count M.
const LAYER_GEMMS: [(usize, usize); 4] =
    [(768, 256), (256, 256), (1024, 256), (256, 1024)];
const N_LAYERS: usize = 4;
const BUCKET_ROWS: usize = 256;

struct Served {
    secs: f64,
    sched_secs: f64,
    flops: f64,
}

fn serve_batch(
    engine: &RealEngine,
    selector: &Selector,
    weights: &[Vec<f32>],
    x_rows: usize,
    rng: &mut Rng,
    verify: bool,
) -> Served {
    let mut sched = 0.0;
    let mut flops = 0.0;
    let t0 = Instant::now();
    let mut wi = 0;
    // Activations flow layer by layer; row count is the dynamic dim.
    let mut act = rng.normal_f32_vec(x_rows * LAYER_GEMMS[0].1);
    for _layer in 0..N_LAYERS {
        for &(n, k) in &LAYER_GEMMS {
            let c = Contraction { m: x_rows, n, k, dtype: DType::F32 };
            let sel = selector.select(c, HwMode::Adaptive).expect("select");
            sched += sel.select_secs;
            let kern = selector.kernel(&sel);
            let w = &weights[wi % weights.len()];
            wi += 1;
            let out = engine
                .gemm_dynamic(&act, &w[..k * n], (x_rows, n, k), kern.l1.to3(), DType::F32)
                .expect("gemm");
            if verify && wi == 1 {
                let want = gemm_host_ref(&act, &w[..k * n], x_rows, n, k);
                let worst = out
                    .iter()
                    .zip(want.iter())
                    .map(|(g, h)| ((g - h).abs() / (1.0 + h.abs())) as f64)
                    .fold(0.0, f64::max);
                assert!(worst < 1e-3, "verification failed: {}", worst);
                println!("  numerics verified vs host ref (worst rel err {:.1e})", worst);
            }
            flops += c.flops();
            act = out;
            // keep activations bounded
            for v in act.iter_mut() {
                *v *= 0.05;
            }
        }
    }
    Served { secs: t0.elapsed().as_secs_f64(), sched_secs: sched, flops }
}

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 24);
    let max_batch = args.get_usize("max-batch", 4);
    let seed = args.get_u64("seed", 7);

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = RealEngine::load(&dir).expect("run `make artifacts` first");
    println!("profiling {} micro-kernel blocks...", engine.manifest.gemm_acc_blocks(DType::F32).len());
    let hw = presets::cpu_pjrt();
    let lib = build_real_library(&engine, &hw, DType::F32, 2).expect("library");
    println!("real library: {} blocks (wall-clock profiled)", lib.kernels.len());
    let selector = Selector::new(hw, vec![lib]);

    // Fixed random weights, biggest size needed (k*n <= 1024*256).
    let mut rng = Rng::new(seed);
    let weights: Vec<Vec<f32>> = (0..4)
        .map(|i| {
            let mut v = rng.normal_f32_vec(1024 * 256);
            let scale = 1.0 / 16.0;
            v.iter_mut().for_each(|x| *x *= scale);
            let _ = i;
            v
        })
        .collect();

    // Request stream: random sequence lengths (token rows).
    let reqs: Vec<usize> = (0..n_requests).map(|_| rng.usize(8, 192)).collect();

    println!("\n== Vortex dynamic serving ({} requests, batch<= {}) ==", n_requests, max_batch);
    let mut metrics = Metrics::default();
    let run0 = Instant::now();
    let mut total_rows = 0usize;
    let mut first = true;
    for batch in reqs.chunks(max_batch) {
        let rows: usize = batch.iter().sum();
        total_rows += rows;
        let served = serve_batch(&engine, &selector, &weights, rows, &mut rng, first);
        first = false;
        metrics.record(
            served.secs,
            served.sched_secs,
            served.secs - served.sched_secs,
            served.flops,
        );
    }
    metrics.span_secs = run0.elapsed().as_secs_f64();
    println!("batches: {}", metrics.count());
    println!("{}", metrics.summary());
    println!(
        "tokens/s: {:.0}   scheduling share: {:.2}%",
        total_rows as f64 / metrics.span_secs,
        100.0 * metrics.sched_fraction()
    );

    println!("\n== Static-bucket baseline (every batch padded to {} rows) ==", BUCKET_ROWS);
    let mut bucket_metrics = Metrics::default();
    let run1 = Instant::now();
    for batch in reqs.chunks(max_batch) {
        // Static-shape compilation pads EVERY request to the sequence
        // bucket (fixed batch x fixed seq) — that is what running a
        // bucketed AOT graph means; the dynamic path above only pays
        // the merged batch's true row count.
        let padded_rows = batch.len() * BUCKET_ROWS;
        let served =
            serve_batch(&engine, &selector, &weights, padded_rows, &mut rng, false);
        bucket_metrics.record(served.secs, served.sched_secs, served.secs, served.flops);
    }
    bucket_metrics.span_secs = run1.elapsed().as_secs_f64();
    println!("{}", bucket_metrics.summary());
    println!(
        "\nVortex dynamic vs static-bucket speedup: {:.2}x",
        bucket_metrics.span_secs / metrics.span_secs
    );
}
